//! Golden-vector tests: checked-in (input, exact, approx) tables for
//! the softfloat baseline quantizers (`lns/softfloat.rs`), the
//! Mitchell / hybrid log-to-linear conversion (`lns/convert.rs`), and
//! the Q_log scalar round-trip (`lns/format.rs`).
//!
//! Purpose: kernel refactors must not silently change numerics. Every
//! expected value below is a literal (computed by hand on the format's
//! dyadic grid, or to >= 9 significant digits for transcendentals), so
//! a behavioural change in any quantizer flips an assert even if the
//! property suite's random draws happen to miss it. The paper's error
//! bounds (half-ulp for minifloats, the Mitchell bound for the hybrid
//! converter, Lemma 1's `2^(1/(2*gamma)) - 1` for Q_log) are asserted
//! against the same checked-in numbers.

use lns_madam::lns::convert::{mitchell_bound, ConvertMode, Converter};
use lns_madam::lns::format::{LnsFormat, Rounding};
use lns_madam::lns::kernels;
use lns_madam::lns::softfloat::MiniFloat;
use lns_madam::lns::Scaling;
use lns_madam::util::rng::{CounterRng, Rng};

// ---------------------------------------------------------------------------
// softfloat: minifloat quantization golden vectors
// ---------------------------------------------------------------------------

/// (input, expected quantized value). Expected values sit exactly on
/// the format's dyadic grid, so the assert is bit-exact equality.
const E4M3_GOLDEN: &[(f32, f32)] = &[
    (1.1, 1.125),          // binade [1,2): ulp 1/8, 8.8 -> 9
    (0.1, 0.1015625),      // binade [1/16,1/8): ulp 2^-7, 12.8 -> 13
    (3.3, 3.25),           // binade [2,4): ulp 1/4, 13.2 -> 13
    (-0.7, -0.6875),       // binade [1/2,1): ulp 2^-4, 11.2 -> 11
    (0.017, 0.017578125),  // binade clamp: ulp 2^-9, 8.704 -> 9
    (0.002, 0.001953125),  // subnormal grid: 1.024 -> 1 step of 2^-9
    (0.0009, 0.0),         // below half a subnormal step: flush to zero
    (1.75, 1.75),          // representable: exact fixed point
    (-2.5, -2.5),          // representable, negative
    (240.0, 240.0),        // max finite value
    (1e9, 240.0),          // saturates
    (-1e9, -240.0),        // saturates, negative
];

const E5M2_GOLDEN: &[(f32, f32)] = &[
    (1.3, 1.25),      // ulp 1/4: 5.2 -> 5
    (0.4, 0.375),     // binade [1/4,1/2): ulp 2^-4, 6.4 -> 6
    (1e6, 57344.0),   // saturates at 1.75 * 2^15
    (-1e6, -57344.0), // saturates, negative
];

const FP16_GOLDEN: &[(f32, f32)] = &[
    (1.1, 1.099609375),      // ulp 2^-10: 1126.4 -> 1126
    (0.3, 0.300048828125),   // binade [1/4,1/2): ulp 2^-12, 1228.8 -> 1229
];

fn check_minifloat(fmt: MiniFloat, golden: &[(f32, f32)]) {
    // Half-ulp relative bound for values in the normal range (the
    // worst case of round-to-nearest on a 2^-mbits grid).
    let bound = 0.5 * (-(fmt.mbits as f32)).exp2();
    for &(x, want) in golden {
        let got = fmt.quantize(x);
        assert_eq!(
            got, want,
            "{fmt:?}: quantize({x}) = {got}, golden table says {want}"
        );
        let mag = x.abs();
        if mag >= fmt.min_normal() && mag < fmt.max_value() {
            let rel = ((got - x) / x).abs();
            assert!(
                rel <= bound + 1e-7,
                "{fmt:?}: quantize({x}) rel err {rel} > half-ulp bound {bound}"
            );
        }
    }
}

#[test]
fn minifloat_golden_vectors() {
    check_minifloat(MiniFloat::E4M3, E4M3_GOLDEN);
    check_minifloat(MiniFloat::E5M2, E5M2_GOLDEN);
    check_minifloat(MiniFloat::FP16, FP16_GOLDEN);
}

// ---------------------------------------------------------------------------
// convert: Mitchell / hybrid / exact-LUT golden vectors (gamma = 8)
// ---------------------------------------------------------------------------

/// One conversion triple: product exponent `p`, the exact value
/// 2^(p/8), and the mode's approximation. `approx` values are exact
/// dyadic products (Mitchell) or sqrt2/2^0.25 products good to f64;
/// `exact` values are checked-in to >= 9 significant digits.
struct ConvertGolden {
    mode: ConvertMode,
    /// Remainder LSB span of the mode at gamma = 8 (for the bound).
    span: u32,
    p: u32,
    exact: f64,
    approx: f64,
}

fn convert_golden_table() -> Vec<ConvertGolden> {
    use ConvertMode::{ExactLut, Hybrid, Mitchell};
    vec![
        // Pure Mitchell: approx = 2^q * (1 + r/8) — dyadic, hand-exact.
        ConvertGolden { mode: Mitchell, span: 8, p: 0, exact: 1.0, approx: 1.0 },
        ConvertGolden {
            mode: Mitchell,
            span: 8,
            p: 3,
            exact: 1.296839554651, // 2^(3/8)
            approx: 1.375,             // 1 + 3/8
        },
        ConvertGolden {
            mode: Mitchell,
            span: 8,
            p: 11,
            exact: 2.593679109302, // 2^(11/8)
            approx: 2.75,              // 2 * (1 + 3/8)
        },
        ConvertGolden {
            mode: Mitchell,
            span: 8,
            p: 21,
            exact: 6.168843301632, // 2^(21/8)
            approx: 6.5,              // 4 * (1 + 5/8)
        },
        ConvertGolden {
            mode: Mitchell,
            span: 8,
            p: 254,                   // top product exponent: 2 * max_code
            exact: 3611622601.0,      // 2^31.75 (9 significant digits)
            approx: 3758096384.0,     // 2^31 * (1 + 6/8) = 1.75 * 2^31
        },
        // Hybrid, 1 LUT bit (entries {1, 2^(4/8)}, span 4).
        ConvertGolden {
            mode: Hybrid { lut_bits: 1 },
            span: 4,
            p: 6,
            exact: 1.681792830507,  // 2^(6/8)
            approx: 1.767766952966, // sqrt2 * (1 + 2/8)
        },
        ConvertGolden {
            mode: Hybrid { lut_bits: 1 },
            span: 4,
            p: 13,
            exact: 3.084421650816, // 2^(13/8)
            approx: 3.181980515339, // 2 * sqrt2 * (1 + 1/8)
        },
        // Hybrid, 2 LUT bits (entries 2^(2i/8), span 2).
        ConvertGolden {
            mode: Hybrid { lut_bits: 2 },
            span: 2,
            p: 11,
            exact: 2.593679109302,  // 2^(11/8)
            approx: 2.675716008756, // 2 * 2^(2/8) * (1 + 1/8)
        },
        // Exact LUT: approximation == exact by construction.
        ConvertGolden {
            mode: ExactLut,
            span: 1,
            p: 11,
            exact: 2.593679109302,
            approx: 2.593679109302,
        },
    ]
}

#[test]
fn mitchell_conversion_golden_vectors() {
    let fmt = LnsFormat::new(8, 8);
    for g in convert_golden_table() {
        // The checked-in exact column really is 2^(p/8).
        let true_exact = (g.p as f64 / 8.0).exp2();
        assert!(
            ((g.exact - true_exact) / true_exact).abs() <= 1e-6,
            "{:?} p={}: golden exact {} vs 2^(p/8) {}",
            g.mode,
            g.p,
            g.exact,
            true_exact
        );
        // The converter reproduces the checked-in approximation.
        let conv = Converter::new(fmt, g.mode);
        let got = conv.convert(g.p);
        assert!(
            ((got - g.approx) / g.approx).abs() <= 1e-9,
            "{:?} p={}: convert = {got}, golden table says {}",
            g.mode,
            g.p,
            g.approx
        );
        // The paper's Mitchell bound holds on the checked-in numbers.
        let bound = mitchell_bound(8, g.span) + 1e-9;
        let rel = ((g.approx - g.exact) / g.exact).abs();
        assert!(
            rel <= bound,
            "{:?} p={}: approx rel err {rel} > Mitchell bound {bound}",
            g.mode,
            g.p
        );
    }
}

// ---------------------------------------------------------------------------
// format: Q_log scalar round-trip golden vectors (PAPER8, scale = 1)
// ---------------------------------------------------------------------------

/// (input, expected round-trip) for `LnsFormat::PAPER8.quantize(x, 1.0)`.
/// Expected values are 2^(code/8) with hand-derived codes, to >= 9
/// significant digits (f32 decode noise is ~1e-7 relative).
const PAPER8_GOLDEN: &[(f32, f64)] = &[
    (1.0, 1.0),                  // code 0
    (2.0, 2.0),                  // code 8: exact octave
    (1.5, 1.542210825408),   // code 5: 2^(5/8)
    (3.0, 3.084421650816),   // code 13: 2^(13/8)
    (100.0, 98.70149282611),  // code 53: 2^(53/8)
    (0.9, 1.0),                  // code -1 clamps to 0: the scale floor
    (1048576.0, 60096.776975),   // code 160 clamps to 127: 2^15.875
];

// ---------------------------------------------------------------------------
// kernels: fused fast-path codes at near-tie inputs (scale = 1.0)
// ---------------------------------------------------------------------------

/// (bits, gamma, input, expected code) for the fused quantizer kernels
/// at `scale = 1.0`. Inputs sit around the code-k/k+1 rounding
/// boundary `2^((k + 0.5)/gamma)` at three distances: well clear of it
/// (1e-3 codes), just outside the near-tie fallback band (2e-4 for
/// gamma=8, whose band is ~8.1e-5 — the fast path must round these
/// correctly *without* libm help), and inside the band (5e-5 / 6e-5,
/// where the kernel must fall back to exact libm). Every margin is
/// provably larger than any faithful libm's 1-ulp wiggle at that
/// magnitude, so the expected codes are portable. Generated offline
/// with an f32-faithful simulation of both paths (zero mismatches over
/// 5.3M adversarial cases).
const NEAR_TIE_GOLDEN: &[(u32, u32, f32, u32)] = &[
    (8, 8, 1.3543729, 4),    // wide, fast path
    (8, 8, 1.3541383, 3),    // wide, fast path
    (8, 8, 1.354279, 4),     // outside band, fast path
    (8, 8, 1.3542321, 3),    // outside band, fast path
    (8, 8, 1.3542614, 4),    // inside band, falls back
    (8, 8, 1.3542497, 3),    // inside band, falls back
    (8, 8, 2.9539082, 13),   // wide, fast path
    (8, 8, 2.9533963, 12),   // wide, fast path
    (8, 8, 2.9537034, 13),   // outside band, fast path
    (8, 8, 2.9536011, 12),   // outside band, fast path
    (8, 8, 2.953665, 13),    // inside band, falls back
    (8, 8, 2.9536395, 12),   // inside band, falls back
    (8, 8, 103.080315, 54),  // wide, fast path
    (8, 8, 103.062454, 53),  // wide, fast path
    (8, 8, 103.073166, 54),  // outside band, fast path
    (8, 8, 103.069595, 53),  // outside band, fast path
    (8, 8, 103.07183, 54),   // inside band, falls back
    (8, 8, 103.07094, 53),   // inside band, falls back
    (8, 8, 6049.604, 101),   // wide, fast path
    (8, 8, 6048.5557, 100),  // wide, fast path
    (8, 8, 6049.1846, 101),  // outside band, fast path
    (8, 8, 6048.975, 100),   // outside band, fast path
    (8, 8, 6049.106, 101),   // inside band, falls back
    (8, 8, 6049.0537, 100),  // inside band, falls back
    (8, 8, 57553.855, 127),  // wide, fast path
    (8, 8, 57543.887, 126),  // wide, fast path
    (8, 8, 57549.867, 127),  // outside band, fast path
    (8, 8, 57547.875, 126),  // outside band, fast path
    (8, 8, 57549.12, 127),   // inside band, falls back
    (8, 8, 57548.62, 126),   // inside band, falls back
    (10, 32, 1.1764097, 8),  // g32 wide, fast path
    (10, 32, 1.1763842, 7),  // g32 wide, fast path
    (10, 32, 1.1763985, 8),  // g32 inside band, falls back
    (10, 32, 1.1763954, 7),  // g32 inside band, falls back
    (10, 32, 76.938866, 201), // g32 wide, fast path
    (10, 32, 76.937195, 200), // g32 wide, fast path
    (10, 32, 76.93813, 201), // g32 inside band, falls back
    (10, 32, 76.93793, 200), // g32 inside band, falls back
    (10, 32, 63441.56, 511), // g32 wide, fast path
    (10, 32, 63440.188, 510), // g32 wide, fast path
    (10, 32, 63441.15, 511), // g32 inside band, falls back
    (10, 32, 63440.598, 510), // g32 inside band, falls back
];

#[test]
fn near_tie_golden_vectors_fast_vs_exact() {
    for &(bits, gamma, x, code) in NEAR_TIE_GOLDEN {
        let fmt = LnsFormat::new(bits, gamma);
        // The checked-in code is what the exact scalar encoder emits...
        let exact = fmt.encode(x, 1.0);
        assert_eq!(
            exact.code, code,
            "{bits}b/g{gamma}: scalar encode({x}) = {}, golden table says {code}",
            exact.code
        );
        assert_eq!(exact.sign, 1);
        // ...and the fused fast-path kernel emits the same bits.
        let mut signs = [0i8; 1];
        let mut codes = [0u32; 1];
        kernels::encode_rows_into(
            &mut signs,
            &mut codes,
            &[x],
            1,
            1,
            fmt,
            Scaling::PerTensor,
            Rounding::Nearest,
            None,
            &[1.0],
            1,
        );
        assert_eq!(
            codes[0], code,
            "{bits}b/g{gamma}: kernel encode({x}) = {}, golden table says {code}",
            codes[0]
        );
        assert_eq!(signs[0], 1);
        // Decode agrees bitwise with the scalar decode.
        let lut = kernels::decode_lut(fmt);
        let want = fmt.decode(exact, 1.0);
        let got = 1.0f32 * lut[code as usize];
        assert_eq!(got.to_bits(), want.to_bits(), "{bits}b/g{gamma}: decode({code})");
    }
}

#[test]
fn near_tie_golden_vectors_simd_lane_flagging() {
    // ISSUE-7: the AVX2 quantizer kernel tests all 8 lanes against the
    // near-tie band at once and patches flagged lanes through the
    // scalar exact-libm fallback. Packing each format's golden inputs
    // into one >= 8-wide row makes the vector path (not the scalar
    // tail) process band-interior and band-exterior lanes side by
    // side; the emitted codes must equal the checked-in table under
    // both SIMD modes. On hosts without AVX2+FMA the Auto pass
    // re-runs the scalar path — the assert is the same.
    use lns_madam::util::simd::{set_mode, SimdMode};
    for (bits, gamma) in [(8u32, 8u32), (10, 32)] {
        let fmt = LnsFormat::new(bits, gamma);
        let group: Vec<(f32, u32)> = NEAR_TIE_GOLDEN
            .iter()
            .filter(|&&(b, g, _, _)| b == bits && g == gamma)
            .map(|&(_, _, x, code)| (x, code))
            .collect();
        assert!(group.len() >= 8, "{bits}b/g{gamma}: group too narrow for the vector path");
        let data: Vec<f32> = group.iter().map(|&(x, _)| x).collect();
        let want: Vec<u32> = group.iter().map(|&(_, code)| code).collect();
        for mode in [SimdMode::Off, SimdMode::Auto] {
            set_mode(mode).unwrap();
            let mut signs = vec![0i8; data.len()];
            let mut codes = vec![0u32; data.len()];
            kernels::encode_rows_into(
                &mut signs,
                &mut codes,
                &data,
                1,
                data.len(),
                fmt,
                Scaling::PerTensor,
                Rounding::Nearest,
                None,
                &[1.0],
                1,
            );
            assert_eq!(codes, want, "{bits}b/g{gamma} under {mode:?}: lane codes diverged");
            assert!(signs.iter().all(|&s| s == 1), "{bits}b/g{gamma} under {mode:?}: signs");
        }
        set_mode(SimdMode::Auto).unwrap();
    }
}

#[test]
fn paper8_quantize_golden_vectors() {
    let fmt = LnsFormat::PAPER8;
    let bound = fmt.max_rel_error();
    for &(x, want) in PAPER8_GOLDEN {
        let got = fmt.quantize(x, 1.0) as f64;
        assert!(
            ((got - want) / want).abs() <= 1e-5,
            "quantize({x}, 1.0) = {got}, golden table says {want}"
        );
        // Lemma-1 bound for in-range inputs (neither clamp engaged).
        let in_range = x >= 1.0 && (x as f64) <= (fmt.dynamic_range_log2()).exp2();
        if in_range {
            let rel = ((got - x as f64) / x as f64).abs();
            assert!(
                rel <= bound * 1.001 + 1e-6,
                "quantize({x}): rel err {rel} > Lemma-1 bound {bound}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// CounterRng: counter-based stochastic-rounding stream golden vectors
// ---------------------------------------------------------------------------

/// (key, counter, expected u64 draw, expected uniform f32). The
/// construction is SplitMix64's finalizer over `key + (i+1)*PHI` —
/// row (0, 0) is therefore exactly SplitMix64's first output from
/// seed 0 — and the f32 uniform is the same 24-bit top-bits
/// construction `Rng::uniform_f32` uses, so every expected value is
/// reproducible from the published reference algorithm. The uniform
/// column is exact (24-bit integers and 2^-24 are exactly
/// representable), so asserts are bitwise. (0, u64::MAX) pins the
/// counter-wrap edge: the wrapped state is 0, whose finalizer image
/// is 0.
const COUNTER_RNG_GOLDEN: &[(u64, u64, u64, f32)] = &[
    (0x0000000000000000, 0x0000000000000000, 0xE220A8397B1DCDAF, 0.8833108),
    (0x0000000000000000, 0x0000000000000001, 0x6E789E6AA1B965F4, 0.43152797),
    (0x0000000000000000, 0x0000000000000002, 0x06C45D188009454F, 0.026433766),
    (0x0000000000000000, 0x0000000000000007, 0xC584133AC916AB3C, 0.77154654),
    (0x0000000000000000, 0x0000000000001FFF, 0x2D2D553455DCDFD4, 0.17647296),
    (0x0000000000000000, 0x0000000100000000, 0x46093CF9861EC2E4, 0.2735784),
    (0x0000000000000000, 0xFFFFFFFFFFFFFFFF, 0x0000000000000000, 0.0),
    (0x0000000000000001, 0x0000000000000000, 0x910A2DEC89025CC1, 0.5665615),
    (0x0000000000000001, 0x0000000000000001, 0xBEEB8DA1658EEC67, 0.7457817),
    (0x0000000000000001, 0x0000000000000002, 0xF893A2EEFB32555E, 0.9710027),
    (0x0000000000000001, 0x0000000000000007, 0x85E7BB0F12278575, 0.5230672),
    (0x0000000000000001, 0x0000000000001FFF, 0x01952A3B83A7C1FC, 0.006182313),
    (0x0000000000000001, 0x0000000100000000, 0x16C3E976BF22DC37, 0.08892685),
    (0x0000000000000001, 0xFFFFFFFFFFFFFFFF, 0x5692161D100B05E5, 0.3381666),
    (0x000000000000DA7A, 0x0000000000000000, 0x5ADBAA8B4F43D880, 0.3549143),
    (0x000000000000DA7A, 0x0000000000000001, 0xE542C1DD1F137FAD, 0.89554983),
    (0x000000000000DA7A, 0x0000000000000002, 0x3BEA9B5F4190F02A, 0.23404855),
    (0x000000000000DA7A, 0x0000000000000007, 0x38190AED91BED9CF, 0.21913207),
    (0x000000000000DA7A, 0x0000000000001FFF, 0x931E28034B1712F2, 0.5746789),
    (0x000000000000DA7A, 0x0000000100000000, 0xE43C8FC34DA5F3F9, 0.89154905),
    (0x000000000000DA7A, 0xFFFFFFFFFFFFFFFF, 0x8744D95DAD46F86D, 0.5283943),
    (0x00000000DEADBEEF, 0x0000000000000000, 0x4ADFB90F68C9EB9B, 0.29247624),
    (0x00000000DEADBEEF, 0x0000000000000001, 0xDE586A3141A10922, 0.8685366),
    (0x00000000DEADBEEF, 0x0000000000000002, 0x021FBC2F8E1CFC1D, 0.008296728),
    (0x00000000DEADBEEF, 0x0000000000000007, 0xB30A4CCF430B1B5A, 0.69937587),
    (0x00000000DEADBEEF, 0x0000000000001FFF, 0x378B755F7F75C37E, 0.2169717),
    (0x00000000DEADBEEF, 0x0000000100000000, 0xDF0AD790901E109C, 0.87125915),
    (0x00000000DEADBEEF, 0xFFFFFFFFFFFFFFFF, 0x4E062702EC929EEA, 0.30478138),
    (0xFFFFFFFFFFFFFFFF, 0x0000000000000000, 0xE4D971771B652C20, 0.8939429),
    (0xFFFFFFFFFFFFFFFF, 0x0000000000000001, 0xE99FF867DBF682C9, 0.9125972),
    (0xFFFFFFFFFFFFFFFF, 0x0000000000000002, 0x382FF84CB27281E9, 0.21948195),
    (0xFFFFFFFFFFFFFFFF, 0x0000000000000007, 0x405DA438A39E8064, 0.25142884),
    (0xFFFFFFFFFFFFFFFF, 0x0000000000001FFF, 0x928F9EE3E7FDE1BA, 0.5725039),
    (0xFFFFFFFFFFFFFFFF, 0x0000000100000000, 0xC5AA1D1D7E827744, 0.772127),
    (0xFFFFFFFFFFFFFFFF, 0xFFFFFFFFFFFFFFFF, 0xB4D055FCF2CBBD7B, 0.7063039),
];

#[test]
fn counter_rng_golden_vectors() {
    for &(key, i, want_u64, want_f32) in COUNTER_RNG_GOLDEN {
        let c = CounterRng::new(key);
        assert_eq!(
            c.u64_at(i),
            want_u64,
            "CounterRng({key:#X}).u64_at({i:#X}) drifted from the golden table"
        );
        assert_eq!(
            c.uniform_f32_at(i).to_bits(),
            want_f32.to_bits(),
            "CounterRng({key:#X}).uniform_f32_at({i:#X}) drifted from the golden table"
        );
    }
}

#[test]
fn stochastic_quant_consumes_exactly_one_sequential_draw_per_call() {
    // The counter construction replaces the per-element pre-draw: a
    // stochastic quantize call advances the caller's sequential stream
    // by exactly one u64 (the key), regardless of tensor size — and
    // the emitted values match the scalar `encode_stochastic` fed the
    // counter stream at each flat index.
    let fmt = LnsFormat::new(8, 8);
    let (rows, cols) = (7, 13);
    let mut seq = Rng::new(0x5EED);
    let data: Vec<f32> = (0..rows * cols).map(|_| seq.normal_f32()).collect();

    let mut rng_a = Rng::new(99);
    let mut rng_b = Rng::new(99);
    let key_rng = CounterRng::from_rng(&mut rng_b); // the draw the kernel makes

    let mut got: Vec<f32> = data.clone();
    let mut scratch = kernels::QuantScratch::default();
    kernels::quantize_rows_into_rounded(
        &mut got,
        rows,
        cols,
        fmt,
        Scaling::PerTensor,
        Rounding::Stochastic,
        Some(&mut rng_a),
        1,
        &mut scratch,
    );
    // One draw consumed: both streams now aligned.
    assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "kernel consumed more than the key draw");

    // Scalar reference over the same counter stream.
    let absmax = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let s = fmt.scale_for_absmax(absmax);
    for (i, (&x, &g)) in data.iter().zip(got.iter()).enumerate() {
        let v = fmt.encode_stochastic(x, s, key_rng.uniform_f32_at(i as u64));
        let want = fmt.decode(v, s);
        assert_eq!(g.to_bits(), want.to_bits(), "element {i}: {g} vs scalar {want}");
    }
}
