//! Chaos suite (ISSUE 10): deterministic fault injection against the
//! real training and serving stacks, enforcing the headline invariant —
//! a run killed at an injected point and auto-resumed is bit-identical
//! (losses, params, checkpoint bytes) to the uninterrupted run — plus
//! crash containment and serving hardening under hostile clients.
//!
//! The fault registry is process-global, so EVERY test here serializes
//! on [`faults_lock`], which also clears the plan on entry and on drop
//! (a panicking test must not leave faults armed for the next one).
//! Production-site chaos tests live only in this file for exactly that
//! reason (see util::fault).

use lns_madam::backend::BackendKind;
use lns_madam::coordinator::{checkpoint, OptKind, TrainConfig, Trainer};
use lns_madam::lns::LnsFormat;
use lns_madam::serve::{bench_clients, serve_listener, ServeEngine, ServeLimits};
use lns_madam::util::fault;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Serialize the suite and guarantee a clean registry on both sides of
/// every test, even one that panics mid-flight.
struct FaultGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Drop for FaultGuard {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn faults_lock() -> FaultGuard {
    static LOCK: Mutex<()> = Mutex::new(());
    let g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::clear();
    FaultGuard(g)
}

/// Fresh scratch dir per test run.
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lns_fault_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The chaos training config: 12 steps, a checkpoint boundary every 4,
/// eval every 5 (so eval rows cross the kill point), streaming CSV.
fn chaos_cfg(model: &str, replicas: usize, dir: &Path) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        format: "lns".into(),
        optimizer: OptKind::Madam,
        lr: OptKind::Madam.default_lr(),
        steps: 12,
        eval_every: 5,
        save_every: 4,
        keep_ckpts: 3,
        replicas,
        backend: BackendKind::Native,
        ckpt_path: dir.join("run.ckpt").to_str().unwrap().into(),
        log_path: dir.join("metrics.csv").to_str().unwrap().into(),
        ..TrainConfig::default()
    }
}

fn loss_bits(t: &Trainer, key: &str) -> BTreeMap<usize, u64> {
    t.log
        .rows
        .iter()
        .filter_map(|r| r.values.get(key).map(|v| (r.step, v.to_bits())))
        .collect()
}

fn param_bits(t: &Trainer) -> Vec<Vec<u32>> {
    t.params.iter().map(|p| p.data.iter().map(|x| x.to_bits()).collect()).collect()
}

/// Every CSV line must have the header's column count — the incremental
/// stream's "parseable prefix after a kill" contract.
fn assert_parseable_csv(path: &Path, min_rows: usize) {
    let text = std::fs::read_to_string(path).unwrap();
    let mut lines = text.lines();
    let header = lines.next().expect("csv has a header");
    assert!(header.starts_with("step"), "unexpected header {header:?}");
    let cols = header.split(',').count();
    let mut rows = 0;
    for l in lines {
        assert_eq!(l.split(',').count(), cols, "ragged csv line {l:?}");
        rows += 1;
    }
    assert!(rows >= min_rows, "crashed csv kept {rows} rows, wanted >= {min_rows}");
}

/// The headline invariant, end to end: train a reference run to
/// completion; train an identical run killed by an injected crash
/// between checkpoint boundaries; auto-resume it from the newest
/// generation; assert per-step losses, eval losses, final params, and
/// the checkpoint files themselves are bit-identical to the reference.
fn kill_and_resume_matches_uninterrupted(model: &str, replicas: usize, tag: &str) {
    let _g = faults_lock();

    // Uninterrupted reference (faults disabled).
    let ref_dir = scratch_dir(&format!("{tag}_ref"));
    let mut reference = Trainer::new(chaos_cfg(model, replicas, &ref_dir)).unwrap();
    reference.run().unwrap();
    assert_eq!(reference.steps_done, 12);

    // Killed run: the injected crash lands after the 7th step — mid
    // checkpoint interval, the worst case for resume.
    let crash_dir = scratch_dir(&format!("{tag}_crash"));
    fault::configure("train_crash:6", 0).unwrap();
    let mut crashed = Trainer::new(chaos_cfg(model, replicas, &crash_dir)).unwrap();
    let err = crashed.run().unwrap_err();
    assert!(err.to_string().contains("train_crash"), "unexpected: {err}");
    assert_eq!(crashed.steps_done, 7);
    fault::clear();

    // The streamed CSV holds a parseable prefix of the killed run
    // (checked before the resumed run truncates and rewrites it).
    assert_parseable_csv(&crash_dir.join("metrics.csv"), 7);

    // Auto-resume picks the newest verified generation (step 4) and
    // finishes the remaining steps under the same command line.
    let mut cfg = chaos_cfg(model, replicas, &crash_dir);
    cfg.resume_from = "auto".into();
    cfg.steps = 12 - 4;
    let mut resumed = Trainer::new(cfg).unwrap();
    assert_eq!(resumed.steps_done, 4, "auto-resume should restore the step-4 boundary");
    resumed.run().unwrap();
    assert_eq!(resumed.steps_done, 12);

    // Losses: every step the resumed run took must match the reference
    // bit-for-bit (eval rows included).
    for key in ["loss", "eval_loss"] {
        let want = loss_bits(&reference, key);
        let got = loss_bits(&resumed, key);
        assert!(!got.is_empty(), "resumed run recorded no {key} rows");
        for (step, bits) in &got {
            assert_eq!(
                Some(bits),
                want.get(step),
                "{key} diverged at step {step} ({model}, replicas {replicas})"
            );
        }
    }

    // Parameters: bit-identical final state.
    assert_eq!(
        param_bits(&reference),
        param_bits(&resumed),
        "final params diverged ({model}, replicas {replicas})"
    );

    // Checkpoint artifacts: the end-of-run file, the retained
    // generations, and the latest pointer are byte-identical.
    let artifacts =
        ["run.ckpt", "run.ckpt.step4", "run.ckpt.step8", "run.ckpt.step12", "run.ckpt.latest"];
    for name in artifacts {
        let a = std::fs::read(ref_dir.join(name)).unwrap();
        let b = std::fs::read(crash_dir.join(name)).unwrap();
        assert_eq!(a, b, "{name} differs between reference and resumed runs");
    }
}

#[test]
fn mlp_kill_and_resume_is_bit_identical_r1() {
    kill_and_resume_matches_uninterrupted("mlp_tiny", 1, "mlp_r1");
}

#[test]
fn mlp_kill_and_resume_is_bit_identical_r4() {
    kill_and_resume_matches_uninterrupted("mlp_tiny", 4, "mlp_r4");
}

#[test]
fn charlm_kill_and_resume_is_bit_identical_r1() {
    kill_and_resume_matches_uninterrupted("charlm_tiny", 1, "charlm_r1");
}

#[test]
fn charlm_kill_and_resume_is_bit_identical_r4() {
    kill_and_resume_matches_uninterrupted("charlm_tiny", 4, "charlm_r4");
}

/// A crashed run whose newest generation was corrupted on disk resumes
/// from the one before it (checksum verification + one-generation
/// fallback), instead of dying or silently training on garbage.
#[test]
fn auto_resume_falls_back_one_generation_when_newest_is_corrupt() {
    let _g = faults_lock();
    let dir = scratch_dir("corrupt_gen");
    fault::configure("train_crash:9", 0).unwrap();
    let mut t = Trainer::new(chaos_cfg("mlp_tiny", 0, &dir)).unwrap();
    t.run().unwrap_err();
    assert_eq!(t.steps_done, 10, "crash should land after step 10 (boundaries 4 and 8 done)");
    fault::clear();

    let base = dir.join("run.ckpt");
    let gen8 = checkpoint::generation_path(&base, 8);
    let mut bytes = std::fs::read(&gen8).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&gen8, &bytes).unwrap();

    let mut cfg = chaos_cfg("mlp_tiny", 0, &dir);
    cfg.resume_from = "auto".into();
    let resumed = Trainer::new(cfg).unwrap();
    assert_eq!(resumed.steps_done, 4, "should fall back to the step-4 generation");
}

/// An injected crash *during* a periodic checkpoint write leaves only
/// a half-written temp file behind; the previous generation and the
/// latest pointer stay intact and the run resumes from them.
#[test]
fn checkpoint_write_crash_resumes_from_previous_generation() {
    let _g = faults_lock();
    let dir = scratch_dir("ckpt_write");
    // Boundary saves are ckpt_write hits 0 (step 4) and 1 (step 8);
    // firing hit 1 kills the run mid-write at the step-8 boundary.
    fault::configure("ckpt_write:1", 0).unwrap();
    let mut t = Trainer::new(chaos_cfg("mlp_tiny", 0, &dir)).unwrap();
    let err = t.run().unwrap_err();
    assert!(err.to_string().contains("ckpt_write"), "unexpected: {err}");
    fault::clear();

    let base = dir.join("run.ckpt");
    assert!(!checkpoint::generation_path(&base, 8).exists(), "step-8 gen must not exist");
    let mut cfg = chaos_cfg("mlp_tiny", 0, &dir);
    cfg.resume_from = "auto".into();
    let resumed = Trainer::new(cfg).unwrap();
    assert_eq!(resumed.steps_done, 4, "should resume from the intact step-4 generation");
}

/// An injected read failure on the newest checkpoint falls through to
/// the next generation (the fallback path handles I/O errors the same
/// way it handles corruption).
#[test]
fn injected_checkpoint_read_falls_back_to_an_older_generation() {
    let _g = faults_lock();
    let dir = scratch_dir("ckpt_read");
    fault::configure("train_crash:9", 0).unwrap();
    Trainer::new(chaos_cfg("mlp_tiny", 0, &dir)).unwrap().run().unwrap_err();

    // ckpt_read hit 0 = the pointer target (step 8): injected failure;
    // the step-4 generation loads on hit 1.
    fault::configure("ckpt_read:0", 0).unwrap();
    let mut cfg = chaos_cfg("mlp_tiny", 0, &dir);
    cfg.resume_from = "auto".into();
    let resumed = Trainer::new(cfg).unwrap();
    assert_eq!(resumed.steps_done, 4);
}

/// A panicking DDP replica surfaces as a clean error on the training
/// thread — not a process abort — and the harness stays usable.
#[test]
fn replica_panic_is_contained_as_an_error() {
    let _g = faults_lock();
    fault::configure("replica_panic:0", 0).unwrap();
    let cfg = TrainConfig {
        model: "mlp_tiny".into(),
        format: "lns".into(),
        optimizer: OptKind::Madam,
        lr: OptKind::Madam.default_lr(),
        steps: 2,
        eval_every: 0,
        replicas: 4,
        backend: BackendKind::Native,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let err = t.step().unwrap_err();
    assert!(
        err.to_string().contains("panicked"),
        "wanted contained panic, got: {err}"
    );
    fault::clear();

    // The same process trains fine afterwards.
    let mut t2 = Trainer::new(cfg).unwrap();
    t2.run().unwrap();
    assert_eq!(t2.steps_done, 2);
}

/// Train a small char-LM and hand back its params for the serve tests.
fn serve_params() -> Vec<lns_madam::coordinator::Param> {
    let cfg = TrainConfig {
        model: "charlm_tiny".into(),
        format: "lns".into(),
        optimizer: OptKind::Madam,
        lr: OptKind::Madam.default_lr(),
        steps: 10,
        eval_every: 0,
        backend: BackendKind::Native,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    t.params
}

fn spawn_server(
    params: &[lns_madam::coordinator::Param],
    limits: ServeLimits,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let mut engine = ServeEngine::from_params(params, LnsFormat::PAPER8, 1).unwrap();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    let handle = std::thread::spawn(move || serve_listener(listener, &mut engine, &limits));
    (addr, handle)
}

fn send_line(stream: &mut TcpStream, line: &[u8]) {
    stream.write_all(line).unwrap();
}

/// Under injected read stalls on every frame, the server still answers
/// every request, keeps responses bit-identical across clients, drains
/// in-flight work at the request budget, and joins all its threads.
#[test]
fn serve_drains_gracefully_under_injected_read_stalls() {
    let _g = faults_lock();
    let params = serve_params();
    fault::configure("serve_read_stall:1.0", 0).unwrap();
    let (addr, server) = spawn_server(&params, ServeLimits::smoke(8, 6));
    let stats = bench_clients(&addr, 3, 2, &[1, 2, 3], 4).unwrap();
    assert!(
        fault::hit_count("serve_read_stall") >= 6,
        "every frame should have passed the stall site"
    );
    fault::clear();
    server.join().unwrap().unwrap();
    assert_eq!(stats.requests, 6);
    assert!(stats.consistent, "stalled readers must not perturb responses");
}

/// Hostile connections — an oversized frame, malformed frames, and a
/// half-frame staller — must not perturb a well-formed client: its
/// responses are byte-identical to a clean run over the same weights.
#[test]
fn hostile_clients_do_not_perturb_well_formed_responses() {
    let _g = faults_lock();
    let params = serve_params();

    let well_formed = |addr: &str| -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut lines = Vec::new();
        for id in [41u64, 42] {
            let mut req = Vec::new();
            lns_madam::serve::wire::write_request(&mut req, id, &[1, 2, 3], 3);
            send_line(&mut s, &req);
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            lines.push(line);
        }
        lines
    };

    // Clean reference pass.
    let (addr, server) = spawn_server(&params, ServeLimits::smoke(8, 2));
    let want = well_formed(&addr);
    server.join().unwrap().unwrap();
    assert!(want.iter().all(|l| l.contains("tokens")), "reference run failed: {want:?}");

    // Hostile pass: stalls injected, abusers connected.
    fault::configure("serve_read_stall:0.5", 7).unwrap();
    let mut limits = ServeLimits::smoke(8, 2);
    limits.max_request_bytes = 4096;
    let (addr, server) = spawn_server(&params, limits);

    // Oversized frame: error + close.
    let mut big = TcpStream::connect(&addr).unwrap();
    let mut payload = vec![b'1'; 64 * 1024];
    payload.push(b'\n');
    big.write_all(&payload).unwrap();
    let mut line = String::new();
    BufReader::new(big.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("cap"), "wanted cap error, got {line:?}");

    // Malformed frames: each answered with an error, connection lives.
    let mut bad = TcpStream::connect(&addr).unwrap();
    let mut badr = BufReader::new(bad.try_clone().unwrap());
    for frame in [&b"not json at all\n"[..], &b"{\"id\":1,\"prompt\":[1,]}\n"[..]] {
        send_line(&mut bad, frame);
        let mut l = String::new();
        badr.read_line(&mut l).unwrap();
        assert!(l.contains("error"), "wanted wire error, got {l:?}");
    }

    // Half-frame staller: sends a prefix and then goes quiet.
    let mut staller = TcpStream::connect(&addr).unwrap();
    staller.write_all(b"{\"id\":9,\"prompt\":[1").unwrap();

    // The well-formed client sees byte-identical responses anyway.
    let got = well_formed(&addr);
    assert_eq!(got, want, "hostile traffic perturbed well-formed responses");
    fault::clear();
    drop(staller);
    server.join().unwrap().unwrap();
}

/// With the engine loop wedged (injected stall) and a queue of depth 1,
/// a flood of requests gets explicit `busy` backpressure instead of
/// unbounded buffering — and the one admitted request is still served.
#[test]
fn full_queue_answers_busy_instead_of_buffering() {
    let _g = faults_lock();
    let params = serve_params();
    fault::configure("serve_engine_stall:1.0", 0).unwrap();
    let mut limits = ServeLimits::smoke(8, 1);
    limits.queue_cap = 1;
    let (addr, server) = spawn_server(&params, limits);

    let mut s = TcpStream::connect(&addr).unwrap();
    let mut flood = Vec::new();
    for id in 0..10u64 {
        lns_madam::serve::wire::write_request(&mut flood, id, &[1], 2);
    }
    s.write_all(&flood).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let (mut busy, mut tokens) = (0, 0);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).unwrap() == 0 {
            break;
        }
        if line.contains("busy: request queue full") {
            busy += 1;
        }
        if line.contains("tokens") {
            tokens += 1;
        }
    }
    fault::clear();
    server.join().unwrap().unwrap();
    assert!(busy >= 1, "flood never saw backpressure");
    assert_eq!(tokens, 1, "exactly the admitted request should be answered");
}

/// Connections beyond the ceiling are refused with `busy` at accept;
/// the connection inside the ceiling is unaffected.
#[test]
fn connection_ceiling_refuses_excess_connections() {
    let _g = faults_lock();
    let params = serve_params();
    let mut limits = ServeLimits::smoke(8, 1);
    limits.max_conns = 1;
    let (addr, server) = spawn_server(&params, limits);

    let mut first = TcpStream::connect(&addr).unwrap();
    // Give the acceptor time to register the first reader.
    std::thread::sleep(std::time::Duration::from_millis(200));

    let second = TcpStream::connect(&addr).unwrap();
    let mut line = String::new();
    let mut r2 = BufReader::new(second);
    r2.read_line(&mut line).unwrap();
    assert!(line.contains("connection limit"), "wanted ceiling busy, got {line:?}");
    line.clear();
    assert_eq!(r2.read_line(&mut line).unwrap(), 0, "excess connection should be closed");

    // The admitted connection still gets served.
    let mut req = Vec::new();
    lns_madam::serve::wire::write_request(&mut req, 5, &[1], 2);
    send_line(&mut first, &req);
    line.clear();
    BufReader::new(first.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("tokens"), "wanted tokens, got {line:?}");
    server.join().unwrap().unwrap();
}

/// A final frame with no trailing newline (client half-closes after
/// writing) is still parsed, served, and answered.
#[test]
fn missing_newline_at_eof_is_still_served() {
    let _g = faults_lock();
    let params = serve_params();
    let (addr, server) = spawn_server(&params, ServeLimits::smoke(8, 1));

    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"{\"id\":9,\"prompt\":[1],\"max_new\":2}").unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    assert!(
        line.contains("\"id\":9") && line.contains("tokens"),
        "wanted tokens for the newline-less frame, got {line:?}"
    );
    server.join().unwrap().unwrap();
}

/// An injected engine failure flushes an error to every in-flight
/// connection before the server surfaces it — clients are never left
/// hanging on a dead engine.
#[test]
fn engine_failure_flushes_errors_to_in_flight_clients() {
    let _g = faults_lock();
    let params = serve_params();
    fault::configure("serve_tick:0", 0).unwrap();
    let (addr, server) = spawn_server(&params, ServeLimits::smoke(8, 4));

    let mut s = TcpStream::connect(&addr).unwrap();
    let mut req = Vec::new();
    lns_madam::serve::wire::write_request(&mut req, 3, &[1, 2], 4);
    send_line(&mut s, &req);
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    fault::clear();
    assert!(
        line.contains("\"id\":3") && line.contains("aborted"),
        "wanted flushed engine error, got {line:?}"
    );
    let err = server.join().unwrap().unwrap_err();
    assert!(err.to_string().contains("serve_tick"), "unexpected: {err}");
}
