//! Conformance suite for the LNS substrate (no artifacts required).
//!
//! Verifies, through the public API only:
//!  * Q_log round-trip error is bounded by the format's `gap_factor`
//!    for random tensors across bitwidths, scalings and both rounding
//!    modes (property-tested);
//!  * the Fig. 6 datapath simulator agrees with the exact
//!    `Tensor::matmul` reference on quantized inputs within the
//!    paper's Mitchell approximation bound, in exact-LUT and every
//!    hybrid mode;
//!  * per-thread `OpCounts` merge to exactly the sequential totals at
//!    any `Parallelism` setting;
//!  * shape-mismatch inputs panic instead of producing garbage.

use lns_madam::lns::convert::mitchell_bound;
use lns_madam::lns::{
    encode_tensor, ConvertMode, LnsFormat, MacConfig, Parallelism, Rounding, Scaling,
    VectorMacUnit,
};
use lns_madam::prop_assert;
use lns_madam::util::proptest::property;
use lns_madam::util::rng::Rng;
use lns_madam::util::tensor::Tensor;

// ---------------------------------------------------------------------------
// Q_log round-trip property
// ---------------------------------------------------------------------------

#[test]
fn roundtrip_error_bounded_by_gap_factor_across_formats_and_roundings() {
    // Nearest rounding lands within half a code (ratio <= gap^0.5);
    // stochastic rounding within one code (ratio < gap). Both are
    // bounded by gap_factor, which is the contract asserted here.
    for (bits, gamma) in [(4u32, 2u32), (6, 4), (8, 8), (8, 16), (12, 64), (16, 2048)] {
        let fmt = LnsFormat::new(bits, gamma);
        let bound = fmt.gap_factor() as f32 * 1.0001; // f32 slack
        for rounding in [Rounding::Nearest, Rounding::Stochastic] {
            for scaling in [Scaling::PerTensor, Scaling::PerRow] {
                property(60, |g| {
                    let rows = g.usize_in(1, 5);
                    let cols = g.usize_in(1, 7);
                    let data: Vec<f32> =
                        (0..rows * cols).map(|_| g.lns_value()).collect();
                    let t = Tensor::from_vec(rows, cols, data);
                    let enc = encode_tensor(&t, fmt, scaling, rounding, Some(&mut g.rng));
                    let dec = enc.decode();
                    for r in 0..rows {
                        for c in 0..cols {
                            let x = t.at(r, c);
                            let q = dec.at(r, c);
                            let scale = enc.scale_at(r, c);
                            if x.abs() < scale {
                                // Below the bottom code: clamps, not a
                                // round-trip — outside the contract.
                                continue;
                            }
                            let ratio = (q / x).abs().max((x / q).abs());
                            prop_assert!(
                                g,
                                ratio <= bound,
                                "bits={bits} gamma={gamma} {rounding:?} {scaling:?}: \
                                 x={x} q={q} ratio={ratio} bound={bound}"
                            );
                            prop_assert!(
                                g,
                                q.signum() == x.signum(),
                                "sign flipped: x={x} q={q}"
                            );
                        }
                    }
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Datapath vs exact reference, within the Mitchell bound
// ---------------------------------------------------------------------------

/// (mode, remainder-LSB span at gamma = 8). Reference leads: it runs
/// a full gamma-entry LUT in the datapath (span 1, exactly ExactLut)
/// rather than silently degrading to Mitchell as it once did.
const MODES: [(ConvertMode, u32); 5] = [
    (ConvertMode::Reference, 1),
    (ConvertMode::ExactLut, 1),
    (ConvertMode::Hybrid { lut_bits: 2 }, 2),
    (ConvertMode::Hybrid { lut_bits: 1 }, 4),
    (ConvertMode::Mitchell, 8),
];

#[test]
fn datapath_matmul_within_mitchell_bound_of_tensor_matmul() {
    let mut rng = Rng::new(404);
    let fmt = LnsFormat::PAPER8;
    let a = Tensor::randn(24, 48, 1.0, &mut rng);
    let b = Tensor::randn(48, 20, 1.0, &mut rng);
    let ea = encode_tensor(&a, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let eb = encode_tensor(&b, fmt, Scaling::PerTensor, Rounding::Nearest, None);

    // Exact reference: decode to the quantized grid, multiply exactly.
    let aq = ea.decode();
    let bq = eb.decode();
    let reference = aq.matmul(&bq);
    // Worst-case accumulation of per-product relative error.
    let abs_ref = aq.map(f32::abs).matmul(&bq.map(f32::abs));
    // Slack for the 24-bit block-window accumulator (swamped lanes).
    let slack = 1e-3 * reference.abs_max().max(1.0);

    for (mode, span) in MODES {
        let mut cfg = MacConfig::paper();
        cfg.convert = mode;
        let mut mac = VectorMacUnit::new(cfg);
        let got = mac.matmul(&ea, &eb);
        let bound = mitchell_bound(fmt.gamma, span) as f32;
        for i in 0..reference.data.len() {
            let err = (got.data[i] - reference.data[i]).abs();
            let budget = bound * abs_ref.data[i] + slack;
            assert!(
                err <= budget,
                "{mode:?}: elem {i} err {err} > bound {budget} \
                 (got {}, ref {})",
                got.data[i],
                reference.data[i]
            );
        }
        assert_eq!(mac.counts.total_macs(), (24 * 48 * 20) as u64);
    }
}

#[test]
fn hybrid_error_shrinks_as_lut_grows() {
    let mut rng = Rng::new(405);
    let fmt = LnsFormat::PAPER8;
    let a = Tensor::randn(16, 64, 1.0, &mut rng);
    let b = Tensor::randn(64, 16, 1.0, &mut rng);
    let ea = encode_tensor(&a, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let eb = encode_tensor(&b, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let reference = ea.decode().matmul(&eb.decode());

    let mut errs = Vec::new();
    for (mode, _) in MODES {
        let mut cfg = MacConfig::paper();
        cfg.convert = mode;
        let mut mac = VectorMacUnit::new(cfg);
        let got = mac.matmul(&ea, &eb);
        let l1: f64 = got
            .data
            .iter()
            .zip(reference.data.iter())
            .map(|(g, r)| (g - r).abs() as f64)
            .sum();
        errs.push(l1);
    }
    // MODES is ordered exact -> coarsest; aggregate error must not
    // shrink as the LUT loses entries. Per-product Mitchell error is
    // not pointwise monotone in the span (the (1+t)/2^t curve turns
    // over near t ~ 0.44), so allow a small statistical slack.
    for w in errs.windows(2) {
        assert!(
            w[0] <= w[1] * 1.1 + 1e-9,
            "error not monotone in LUT size: {errs:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// LnsExec training tier: every GEMM orientation within the bound
// ---------------------------------------------------------------------------

#[test]
fn lns_exec_gemms_within_mitchell_bound_in_every_orientation() {
    use lns_madam::lns::exec::{lns_matmul_into, lns_matmul_t_into, lns_t_matmul_into};
    use lns_madam::lns::{quantize_tensor, ExecScratch, LnsExecCfg, OpCounts};

    let mut rng = Rng::new(408);
    let fmt = LnsFormat::PAPER8;
    let (m, k, n) = (14usize, 40usize, 11usize);
    let a = Tensor::randn(m, k, 1.0, &mut rng);
    let b = Tensor::randn(k, n, 1.0, &mut rng);
    // The tier re-encodes through the same PerTensor/Nearest pipeline,
    // so the quantized grid is the exact reference surface.
    let aq = quantize_tensor(&a, fmt, Scaling::PerTensor);
    let bq = quantize_tensor(&b, fmt, Scaling::PerTensor);
    let reference = aq.matmul(&bq);
    let abs_ref = aq.map(f32::abs).matmul(&bq.map(f32::abs));
    let slack = 1e-3 * reference.abs_max().max(1.0);

    // Pre-transposed copies for the t_matmul / matmul_t orientations.
    let mut at = Tensor::zeros(k, m);
    for i in 0..m {
        for j in 0..k {
            at.data[j * m + i] = a.data[i * k + j];
        }
    }
    let mut bt = Tensor::zeros(n, k);
    for i in 0..k {
        for j in 0..n {
            bt.data[j * k + i] = b.data[i * n + j];
        }
    }

    for (mode, span) in MODES {
        let cfg = LnsExecCfg { fmt, convert: mode, acc_bits: 24 };
        let bound = mitchell_bound(fmt.gamma, span) as f32;
        let mut scratch = ExecScratch::new();
        let mut outs = [Tensor::zeros(m, n), Tensor::zeros(m, n), Tensor::zeros(m, n)];
        let mut counts = OpCounts::default();
        lns_matmul_into(
            &mut outs[0].data,
            &a.data,
            &b.data,
            m,
            k,
            n,
            cfg,
            2,
            &mut scratch,
            &mut counts,
        );
        lns_t_matmul_into(
            &mut outs[1].data,
            &at.data,
            &b.data,
            m,
            k,
            n,
            cfg,
            2,
            &mut scratch,
            &mut counts,
        );
        lns_matmul_t_into(
            &mut outs[2].data,
            &a.data,
            &bt.data,
            m,
            k,
            n,
            cfg,
            2,
            &mut scratch,
            &mut counts,
        );
        for (o, out) in outs.iter().enumerate() {
            for i in 0..reference.data.len() {
                let err = (out.data[i] - reference.data[i]).abs();
                let budget = bound * abs_ref.data[i] + slack;
                assert!(
                    err <= budget,
                    "{mode:?} orientation {o}: elem {i} err {err} > budget {budget}"
                );
            }
        }
        // Measured work: one MAC per (i, j, lane) per orientation.
        assert_eq!(counts.total_macs(), 3 * (m * k * n) as u64);
    }
}

// ---------------------------------------------------------------------------
// Parallelism conformance
// ---------------------------------------------------------------------------

#[test]
fn parallel_op_counts_and_outputs_match_sequential_exactly() {
    let mut rng = Rng::new(406);
    let fmt = LnsFormat::PAPER8;
    // Ragged sizes so worker chunks are uneven.
    let a = Tensor::randn(45, 33, 1.0, &mut rng);
    let b = Tensor::randn(33, 27, 1.0, &mut rng);
    let ea = encode_tensor(&a, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let eb = encode_tensor(&b, fmt, Scaling::PerTensor, Rounding::Nearest, None);

    for (mode, _) in MODES {
        let mut cfg = MacConfig::paper();
        cfg.convert = mode;
        let mut seq = VectorMacUnit::new(cfg);
        let want = seq.matmul(&ea, &eb);
        for par in [
            Parallelism::Threads(2),
            Parallelism::Threads(5),
            Parallelism::Auto,
        ] {
            let mut cfg_p = cfg;
            cfg_p.parallelism = par;
            let mut mac = VectorMacUnit::new(cfg_p);
            let got = mac.matmul(&ea, &eb);
            assert_eq!(got.data, want.data, "{mode:?} {par:?}: outputs diverged");
            assert_eq!(
                mac.counts, seq.counts,
                "{mode:?} {par:?}: op counts diverged"
            );
        }
    }
}

#[test]
fn parallel_counts_accumulate_across_calls() {
    // A reused unit must keep summing counts over multiple parallel
    // GEMMs, exactly like the sequential unit does.
    let mut rng = Rng::new(407);
    let fmt = LnsFormat::PAPER8;
    let a = Tensor::randn(10, 12, 1.0, &mut rng);
    let b = Tensor::randn(12, 8, 1.0, &mut rng);
    let ea = encode_tensor(&a, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let eb = encode_tensor(&b, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let mut cfg = MacConfig::paper();
    cfg.parallelism = Parallelism::Threads(3);
    let mut mac = VectorMacUnit::new(cfg);
    let _ = mac.matmul(&ea, &eb);
    let _ = mac.matmul(&ea, &eb);
    assert_eq!(mac.counts.total_macs(), 2 * (10 * 12 * 8) as u64);
}

// ---------------------------------------------------------------------------
// Shape-mismatch edges
// ---------------------------------------------------------------------------

#[test]
#[should_panic(expected = "matmul shape mismatch")]
fn tensor_matmul_shape_mismatch_panics() {
    let _ = Tensor::zeros(2, 3).matmul(&Tensor::zeros(4, 2));
}

#[test]
#[should_panic(expected = "t_matmul shape mismatch")]
fn tensor_t_matmul_shape_mismatch_panics() {
    let _ = Tensor::zeros(2, 3).t_matmul(&Tensor::zeros(4, 2));
}

#[test]
#[should_panic(expected = "matmul_t shape mismatch")]
fn tensor_matmul_t_shape_mismatch_panics() {
    let _ = Tensor::zeros(2, 3).matmul_t(&Tensor::zeros(4, 2));
}

#[test]
#[should_panic(expected = "matmul shape mismatch")]
fn datapath_matmul_shape_mismatch_panics() {
    let fmt = LnsFormat::PAPER8;
    let a = Tensor::zeros(2, 3);
    let b = Tensor::zeros(4, 2);
    let ea = encode_tensor(&a, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let eb = encode_tensor(&b, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let mut mac = VectorMacUnit::new(MacConfig::paper());
    let _ = mac.matmul(&ea, &eb);
}
