//! End-to-end tests for the LNS-native serving path: train a tiny
//! char-LM natively, checkpoint it, and serve it — asserting the
//! weight-store round-trip, the batching/worker bit-exactness
//! contract, and the TCP wire behavior with concurrent clients.
//!
//! This suite has NO skip paths (see tests/native_training.rs header).

use lns_madam::backend::BackendKind;
use lns_madam::coordinator::{checkpoint, OptKind, Param, TrainConfig, Trainer};
use lns_madam::lns::LnsFormat;
use lns_madam::serve::{
    bench_clients, serve_listener, LnsWeightStore, Sequence, ServeEngine, ServeLimits,
};
use std::path::PathBuf;

/// Train charlm_tiny for a few steps and return its checkpoint params.
fn trained_params(tag: &str) -> (Vec<Param>, PathBuf) {
    let dir = std::env::temp_dir().join(format!("lns_serve_test_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("m.ckpt");
    let cfg = TrainConfig {
        model: "charlm_tiny".into(),
        format: "lns".into(),
        optimizer: OptKind::Madam,
        lr: OptKind::Madam.default_lr(),
        steps: 30,
        eval_every: 0,
        backend: BackendKind::Native,
        ckpt_path: ckpt.to_str().unwrap().into(),
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(cfg).unwrap();
    trainer.run().unwrap();
    let (params, step, _) = checkpoint::load(&ckpt).unwrap();
    assert_eq!(step, 30);
    (params, ckpt)
}

#[test]
fn weight_store_round_trips_a_trained_checkpoint_bitwise() {
    let (params, _) = trained_params("roundtrip");
    let fmt = LnsFormat::PAPER8;
    let store = LnsWeightStore::from_params(&params, fmt, 2).unwrap();
    assert!(
        store.resident_bytes() * 3 <= store.f32_bytes(),
        "store {} bytes vs f32 {} exceeds the 1/3 budget",
        store.resident_bytes(),
        store.f32_bytes()
    );
    for (idx, p) in params.iter().enumerate() {
        // Independent scalar reference: per-element LnsFormat
        // encode/decode with the per-tensor scale.
        let absmax = p.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = fmt.scale_for_absmax(absmax);
        let want: Vec<u32> = p
            .data
            .iter()
            .map(|&x| fmt.decode(fmt.encode(x, scale), scale).to_bits())
            .collect();
        let mut got = vec![0.0f32; p.data.len()];
        store.decode_into(idx, &mut got, 3);
        let got: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "store round-trip diverged for '{}'", p.name);
    }
}

#[test]
fn batched_serving_matches_one_at_a_time_on_a_trained_model() {
    // The batching-invariance property, over a *trained* checkpoint
    // (engine unit tests cover random init): responses identical
    // whether requests run solo or coalesced, at any worker count.
    let (params, _) = trained_params("batching");
    let prompts: Vec<Vec<u32>> =
        vec![vec![0, 1, 2], vec![7, 6], vec![3], vec![1, 1, 1, 1], vec![5, 0, 2]];
    let mut solo = ServeEngine::from_params(&params, LnsFormat::PAPER8, 1).unwrap();
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| solo.generate(i as u64, p, 7).unwrap())
        .collect();

    for workers in [1usize, 2, 4] {
        let mut engine = ServeEngine::from_params(&params, LnsFormat::PAPER8, workers).unwrap();
        let mut active: Vec<Sequence> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Sequence::new(i as u64, p, 7).unwrap())
            .collect();
        for _ in 0..7 {
            engine.tick(&mut active).unwrap();
        }
        for s in &active {
            assert_eq!(
                s.generated, want[s.id as usize],
                "sequence {} diverged (workers {workers})",
                s.id
            );
        }
    }
}

#[test]
fn tcp_serving_answers_concurrent_clients_consistently() {
    let (params, _) = trained_params("tcp");
    let mut engine = ServeEngine::from_params(&params, LnsFormat::PAPER8, 2).unwrap();
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    // 3 clients x 2 requests each = 6 responses, then the loop exits.
    let limits = ServeLimits::smoke(64, 6);
    let server = std::thread::spawn(move || serve_listener(listener, &mut engine, &limits));
    let stats = bench_clients(&addr, 3, 2, &[1, 2, 3], 5).unwrap();
    server.join().unwrap().unwrap();
    assert_eq!(stats.requests, 6);
    assert_eq!(stats.tokens_generated, 30);
    assert!(stats.consistent, "identical prompts got different responses");
    assert!(stats.p50_ms.is_finite() && stats.p99_ms >= stats.p50_ms);
}

#[test]
fn tcp_serving_rejects_bad_requests_without_dying() {
    use std::io::{BufRead, BufReader, Write};
    let (params, _) = trained_params("badreq");
    let mut engine = ServeEngine::from_params(&params, LnsFormat::PAPER8, 1).unwrap();
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    // Malformed-JSON errors are answered by the reader thread and do
    // not count toward max_requests; engine-level rejections and real
    // responses do. Budget: out-of-vocab rejection + good request = 2.
    let limits = ServeLimits::smoke(64, 2);
    let server = std::thread::spawn(move || serve_listener(listener, &mut engine, &limits));

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    // Malformed JSON -> wire error, connection stays up.
    stream.write_all(b"{\"id\":1,\"prompt\":[1,]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "wanted wire error, got {line:?}");

    // Out-of-vocab token -> engine rejection with the request id.
    line.clear();
    stream.write_all(b"{\"id\":2,\"prompt\":[9999]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.contains("\"id\":2") && line.contains("out of vocab"),
        "wanted vocab rejection, got {line:?}"
    );

    // The same connection still serves a good request.
    line.clear();
    stream.write_all(b"{\"id\":3,\"prompt\":[1],\"max_new\":2}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.contains("\"id\":3") && line.contains("tokens"),
        "wanted tokens, got {line:?}"
    );
    drop(stream);
    server.join().unwrap().unwrap();
}

#[test]
fn tcp_serving_caps_oversized_requests_without_buffering_them() {
    use std::io::{BufRead, BufReader, Write};
    let (params, _) = trained_params("oversize");
    let mut engine = ServeEngine::from_params(&params, LnsFormat::PAPER8, 1).unwrap();
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    let mut limits = ServeLimits::smoke(64, 1);
    limits.max_request_bytes = 4096;
    let server = std::thread::spawn(move || serve_listener(listener, &mut engine, &limits));

    // A multi-megabyte line: the reader must answer and close at the
    // 4 KiB cap — never buffer the whole thing (the old reader's
    // unbounded read_until would have).
    let mut abuser = std::net::TcpStream::connect(&addr).unwrap();
    let mut payload = vec![b'7'; 3 * 1024 * 1024];
    payload.push(b'\n');
    abuser.write_all(&payload).unwrap();
    let mut reader = BufReader::new(abuser.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    assert!(
        reply.contains("error") && reply.contains("cap"),
        "wanted byte-cap error, got {reply:?}"
    );
    // The connection is then closed cleanly (EOF, not a reset that
    // could have destroyed the error response above).
    reply.clear();
    assert_eq!(reader.read_line(&mut reply).unwrap(), 0, "connection should be closed");

    // A fresh well-formed client is still served.
    let mut good = std::net::TcpStream::connect(&addr).unwrap();
    good.write_all(b"{\"id\":5,\"prompt\":[1],\"max_new\":2}\n").unwrap();
    let mut line = String::new();
    BufReader::new(good.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(
        line.contains("\"id\":5") && line.contains("tokens"),
        "wanted tokens, got {line:?}"
    );
    server.join().unwrap().unwrap();
}

#[test]
fn serve_cli_config_rejects_missing_checkpoint_file() {
    use lns_madam::coordinator::ServeConfig;
    let cfg = ServeConfig {
        ckpt_path: "definitely_missing.ckpt".into(),
        ..ServeConfig::default()
    };
    let err = lns_madam::serve::run(&cfg).unwrap_err();
    assert!(
        err.to_string().contains("definitely_missing.ckpt"),
        "unexpected error: {err}"
    );
}
