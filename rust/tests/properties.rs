//! Property tests for the numeric substrate (seeded pseudo-random
//! inputs, no external deps — the harness is `util::proptest`).
//!
//! Three invariant families from the paper:
//!
//! * **Quantizer idempotence** — `Q(Q(x)) == Q(x)` for every Q_W/Q_G
//!   format (multi-base LNS across bitwidths and gammas, FP8, INT):
//!   quantized tensors are fixed points of their own quantizer, so the
//!   Fig. 3 placement never compounds error across re-application.
//! * **Madam multiplicative-update invariants** — sign preservation,
//!   zero fixed points, the bounded log-space step, and descent-
//!   direction monotonicity (Algorithm 1 / Fig. 1), for both the
//!   reference `Madam` and the fused Madam+Q_U hot path.
//! * **Lemma-1 bounded relative error** — the LNS round-trip stays
//!   within `2^(1/(2*gamma)) - 1` of the input, checked against an
//!   exact f64 reference encoder so the f32 production path can drift
//!   at most one rounding-tie code from the mathematical definition.

use lns_madam::lns::format::{LnsFormat, LnsValue, Rounding};
use lns_madam::lns::kernels::{self, QuantScratch};
use lns_madam::lns::quant::group_scales;
use lns_madam::lns::Scaling;
use lns_madam::model::QuantKind;
use lns_madam::optim::{FusedMadamQu, Madam, Optimizer, UpdateQuantizer};
use lns_madam::util::proptest::property;
use lns_madam::util::rng::{CounterRng, Rng};
use lns_madam::util::tensor::Tensor;

fn lns_kind(bits: u32, gamma: u32) -> QuantKind {
    QuantKind::Lns { fmt: LnsFormat::new(bits, gamma), scaling: Scaling::PerTensor }
}

#[test]
fn quantizer_idempotence_across_formats() {
    let kinds = [
        lns_kind(8, 8),
        lns_kind(8, 4),
        lns_kind(8, 16),
        lns_kind(6, 8),
        lns_kind(12, 128),
        lns_kind(4, 2),
        QuantKind::Fp8,
        QuantKind::Int { bits: 8 },
        QuantKind::Int { bits: 4 },
    ];
    for kind in kinds {
        property(120, |g| {
            let rows = g.usize_in(1, 8);
            let cols = g.usize_in(1, 8);
            let data: Vec<f32> = (0..rows * cols)
                .map(|_| match g.usize_in(0, 9) {
                    0 => 0.0,                // zero lanes are fixed points too
                    1..=4 => g.normal_f32(), // moderate magnitudes
                    _ => g.lns_value(),      // many binades (the LNS shape)
                })
                .collect();
            let t = Tensor::from_vec(rows, cols, data);
            let once = kind.apply(&t);
            let twice = kind.apply(&once);
            for (a, b) in once.data.iter().zip(twice.data.iter()) {
                // Equality up to f32 scale-recompute noise, which sits
                // ~5 orders below any format's quantization gap.
                assert!(
                    (a - b).abs() <= 2e-6 * a.abs().max(1e-30),
                    "{kind:?}: Q(Q(x)) = {b} != Q(x) = {a}"
                );
            }
        });
    }
}

#[test]
fn madam_update_sign_zero_and_direction_invariants() {
    property(400, |g| {
        let n = g.usize_in(1, 32);
        let before: Vec<f32> = (0..n)
            .map(|_| if g.usize_in(0, 9) == 0 { 0.0 } else { g.lns_value() })
            .collect();
        let grad: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let lr = g.f32_in(1e-4, 0.3);
        let mut opt = Madam::new(lr);
        let mut w = before.clone();
        opt.step(0, &mut w, &grad);
        for i in 0..n {
            let (a, b) = (before[i], w[i]);
            if a == 0.0 {
                // Multiplicative updates cannot leave zero.
                assert_eq!(b, 0.0, "zero weight moved to {b}");
                continue;
            }
            assert!(a.signum() == b.signum(), "sign flipped: {a} -> {b}");
            // |log2|w'| - log2|w|| <= max_step (the bounded
            // multiplicative step), up to log/exp f32 round-trip noise.
            let dlog = (b.abs().log2() - a.abs().log2()).abs();
            assert!(
                dlog <= opt.max_step + 1e-3,
                "log-step {dlog} exceeds max_step {} (w {a} -> {b})",
                opt.max_step
            );
            // Monotone descent direction: gradient aligned with the
            // weight sign shrinks the magnitude, anti-aligned grows it.
            if grad[i] * a.signum() > 0.0 {
                assert!(b.abs() <= a.abs() * 1.00001, "should shrink: {a} -> {b}");
            } else if grad[i] * a.signum() < 0.0 {
                assert!(b.abs() >= a.abs() * 0.99999, "should grow: {a} -> {b}");
            }
        }
    });
}

#[test]
fn fused_madam_qu_preserves_the_same_invariants() {
    let fmt = match UpdateQuantizer::lns_matched(16) {
        UpdateQuantizer::Lns(f) => f,
        _ => unreachable!(),
    };
    property(200, |g| {
        let n = g.usize_in(2, 64);
        let before: Vec<f32> = (0..n)
            .map(|_| {
                if g.usize_in(0, 9) == 0 {
                    0.0
                } else {
                    // +-5 octaves keeps every weight far inside the
                    // ~15.9-octave Q_U range, so no range clamping.
                    let mag = g.f64_in(-5.0, 5.0).exp2();
                    (if g.bool() { -mag } else { mag }) as f32
                }
            })
            .collect();
        let grad: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut opt = FusedMadamQu::new(0.01, fmt);
        opt.par_threshold = usize::MAX; // deterministic single-thread
        let mut w = before.clone();
        opt.step(0, &mut w, &grad);
        // The fused step additionally rounds onto the Q_U grid: the
        // log-space movement is bounded by max_step plus one grid gap
        // (and the fastmath kernels' ~5e-7 noise).
        let gap = 1.0 / fmt.gamma as f32;
        for i in 0..n {
            let (a, b) = (before[i], w[i]);
            if a == 0.0 {
                assert_eq!(b, 0.0, "zero weight moved to {b}");
                continue;
            }
            assert!(a.signum() == b.signum(), "sign flipped: {a} -> {b}");
            let dlog = (b.abs().log2() - a.abs().log2()).abs();
            assert!(
                dlog <= opt.max_step + 2.0 * gap + 1e-3,
                "fused log-step {dlog} out of bounds (w {a} -> {b})"
            );
        }
    });
}

/// Exact f64 reference of the Q_log round-trip (Section 3): the
/// mathematical definition the f32 production encoder approximates.
fn quantize_f64_reference(x: f64, scale: f64, fmt: LnsFormat) -> f64 {
    if x == 0.0 {
        return 0.0;
    }
    let e = ((x.abs() / scale).log2() * fmt.gamma as f64).round_ties_even();
    let e = e.clamp(0.0, fmt.max_code() as f64);
    x.signum() * scale * (e / fmt.gamma as f64).exp2()
}

#[test]
fn lemma1_relative_error_bounded_vs_f64_reference() {
    for fmt in [
        LnsFormat::new(8, 8),
        LnsFormat::new(8, 4),
        LnsFormat::new(8, 16),
        LnsFormat::new(6, 8),
        LnsFormat::new(12, 128),
        LnsFormat::new(16, 2048),
    ] {
        let range = fmt.dynamic_range_log2();
        let bound = fmt.max_rel_error();
        property(300, |g| {
            let mag = g.f64_in(-3.0, 3.0).exp2();
            let x = (if g.bool() { -mag } else { mag }) as f32;
            // Place x interior to the code range: between 1 octave and
            // (range - 1) octaves below the group absmax, so neither
            // clamp engages and Lemma 1 applies.
            let above = g.f64_in(1.0, range - 1.0);
            let scale = fmt.scale_for_absmax((x.abs() as f64 * above.exp2()) as f32);

            // The f64 reference satisfies the Lemma-1 bound exactly.
            let q64 = quantize_f64_reference(x as f64, scale as f64, fmt);
            let rel64 = ((q64 - x as f64) / x as f64).abs();
            assert!(
                rel64 <= bound + 1e-9,
                "{fmt:?}: f64 reference rel err {rel64} > bound {bound} (x={x})"
            );

            // The f32 production path tracks the reference to within
            // one code (rounding-tie flips only) and itself stays
            // within the bound up to f32 noise.
            let q = fmt.quantize(x, scale) as f64;
            let ratio = (q / q64).abs();
            let ratio = ratio.max(1.0 / ratio);
            assert!(
                ratio <= fmt.gap_factor() * (1.0 + 1e-6),
                "{fmt:?}: f32 path {q} vs f64 reference {q64} differ by >1 code (x={x})"
            );
            // The f32 encoder places codes with f32 log2 noise, so a
            // draw near a rounding tie may land one code off the
            // reference; its error is still bounded by a full code gap
            // (2^(1/gamma) - 1, twice the Lemma-1 half-gap bound).
            let rel32 = ((q - x as f64) / x as f64).abs();
            assert!(
                rel32 <= (fmt.gap_factor() - 1.0) + 1e-6,
                "{fmt:?}: f32 rel err {rel32} > one-code bound (x={x})"
            );
        });
    }
}

/// The exact pre-kernel reference: scalar `LnsFormat::encode` /
/// `encode_stochastic` per element over `group_scales`, in row-major
/// order — the semantics the fused kernels must reproduce bit for
/// bit. Stochastic uniforms use the kernels' counter construction:
/// one key drawn from the sequential stream, then a pure per-index
/// draw (`CounterRng::uniform_f32_at(flat index)`).
fn exact_encode_reference(
    t: &Tensor,
    fmt: LnsFormat,
    scaling: Scaling,
    rounding: Rounding,
    rng: Option<&mut Rng>,
) -> (Vec<i8>, Vec<u32>, Vec<f32>) {
    let scales = group_scales(t, fmt, scaling);
    let crng = match rng {
        Some(r) => CounterRng::from_rng(r),
        None => CounterRng::from_rng(&mut Rng::new(0)),
    };
    let mut signs = vec![0i8; t.len()];
    let mut codes = vec![0u32; t.len()];
    let mut decoded = vec![0.0f32; t.len()];
    for r in 0..t.rows {
        for c in 0..t.cols {
            let i = r * t.cols + c;
            let s = match scaling {
                Scaling::PerTensor => scales[0],
                Scaling::PerRow => scales[r],
                Scaling::PerCol => scales[c],
            };
            let v: LnsValue = match rounding {
                Rounding::Nearest => fmt.encode(t.data[i], s),
                Rounding::Stochastic => {
                    fmt.encode_stochastic(t.data[i], s, crng.uniform_f32_at(i as u64))
                }
            };
            signs[i] = v.sign;
            codes[i] = v.code;
            decoded[i] = fmt.decode(v, s);
        }
    }
    (signs, codes, decoded)
}

/// Tensor data slanted toward the quantizer's hard cases: zeros, many
/// binades, and values engineered to straddle a code's rounding
/// boundary (including inside the near-tie fallback band).
fn quantizer_stress_data(
    g: &mut lns_madam::util::proptest::Gen,
    n: usize,
    fmt: LnsFormat,
) -> Vec<f32> {
    (0..n)
        .map(|_| match g.usize_in(0, 9) {
            0 => 0.0,
            1..=3 => g.normal_f32(),
            4..=6 => g.lns_value(),
            _ => {
                // Near-tie construction: 2^((k + 0.5 + d)/gamma), with
                // d spanning well inside to well outside the band.
                let k = g.usize_in(0, fmt.max_code().saturating_sub(1) as usize) as f64;
                let d = g.f64_in(-3e-3, 3e-3);
                let mag = ((k + 0.5 + d) / fmt.gamma as f64).exp2();
                (if g.bool() { -mag } else { mag }) as f32
            }
        })
        .collect()
}

#[test]
fn fast_kernels_bit_identical_to_exact_encode() {
    // ISSUE-4 acceptance: fused fast-path codes == scalar exact codes,
    // bit for bit, across formats (gamma 1..=32, bits 4..=12, plus the
    // 16-bit Q_U format), scalings, and rounding modes.
    let mut formats = Vec::new();
    for bits in [4u32, 6, 8, 10, 12] {
        for glog in 0..=5u32 {
            formats.push(LnsFormat::new(bits, 1 << glog));
        }
    }
    formats.push(LnsFormat::new(16, 2048));
    for fmt in formats {
        for scaling in [Scaling::PerTensor, Scaling::PerRow, Scaling::PerCol] {
            for rounding in [Rounding::Nearest, Rounding::Stochastic] {
                property(12, |g| {
                    let rows = g.usize_in(1, 10);
                    let cols = g.usize_in(1, 10);
                    let t =
                        Tensor::from_vec(rows, cols, quantizer_stress_data(g, rows * cols, fmt));
                    let seed = 0xFEED ^ g.case as u64;
                    let mut rng_ref = Rng::new(seed);
                    let (signs, codes, decoded) =
                        exact_encode_reference(&t, fmt, scaling, rounding, Some(&mut rng_ref));

                    // Plane encode through the kernels.
                    let workers = g.usize_in(1, 6);
                    let scales = group_scales(&t, fmt, scaling);
                    let mut got_s = vec![0i8; t.len()];
                    let mut got_c = vec![0u32; t.len()];
                    let mut rng_enc = Rng::new(seed);
                    let mut scratch = QuantScratch::default();
                    kernels::encode_rows_into(
                        &mut got_s,
                        &mut got_c,
                        &t.data,
                        rows,
                        cols,
                        fmt,
                        scaling,
                        rounding,
                        Some(&mut rng_enc),
                        &scales,
                        workers,
                    );
                    lns_madam::prop_assert!(
                        g,
                        got_s == signs && got_c == codes,
                        "{fmt:?} {scaling:?} {rounding:?}: kernel planes diverge from exact"
                    );

                    // Fused round-trip agrees with exact decode bitwise.
                    let mut rt = t.clone();
                    let mut rng_rt = Rng::new(seed);
                    kernels::quantize_rows_into_rounded(
                        &mut rt.data,
                        rows,
                        cols,
                        fmt,
                        scaling,
                        rounding,
                        Some(&mut rng_rt),
                        workers,
                        &mut scratch,
                    );
                    for (a, b) in rt.data.iter().zip(decoded.iter()) {
                        lns_madam::prop_assert!(
                            g,
                            a.to_bits() == b.to_bits(),
                            "{fmt:?} {scaling:?} {rounding:?}: roundtrip {a} vs exact {b}"
                        );
                    }
                });
            }
        }
    }
}

#[test]
fn parallel_quantization_bit_identical_across_threads() {
    // Cross-thread determinism of the fused quantizer: any worker
    // count produces the sequential bits, for every scaling.
    property(60, |g| {
        let rows = g.usize_in(1, 24);
        let cols = g.usize_in(1, 24);
        let fmt = LnsFormat::new(8, 8);
        let t = Tensor::from_vec(rows, cols, quantizer_stress_data(g, rows * cols, fmt));
        for scaling in [Scaling::PerTensor, Scaling::PerRow, Scaling::PerCol] {
            let mut scratch = QuantScratch::default();
            let mut want = t.clone();
            kernels::quantize_rows_into(&mut want.data, rows, cols, fmt, scaling, 1, &mut scratch);
            for workers in [2usize, 3, 5, 8, 64] {
                let mut got = t.clone();
                kernels::quantize_rows_into(
                    &mut got.data,
                    rows,
                    cols,
                    fmt,
                    scaling,
                    workers,
                    &mut scratch,
                );
                lns_madam::prop_assert!(
                    g,
                    got.data
                        .iter()
                        .zip(want.data.iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{scaling:?} @ {workers} workers diverged from sequential"
                );
            }
        }
    });
}

#[test]
fn parallel_quantization_bit_identical_above_worker_floor() {
    // Small tensors scale the worker count down to 1 (the shared
    // `pool::QUANT_ELEMS_PER_WORKER` floor), so the property above
    // mostly proves the clamp. This one uses shapes big enough for
    // genuine multi-way bands — the surface where offset/indexing
    // bugs would live, especially the stochastic path's
    // counter-indexed uniform draws.
    let fmt = LnsFormat::new(8, 8);
    let (rows, cols) = (193, 307); // 59k elements, ragged over workers
    let mut rng = Rng::new(0xA11);
    let t = Tensor::randn(rows, cols, 1.0, &mut rng);
    for scaling in [Scaling::PerTensor, Scaling::PerRow, Scaling::PerCol] {
        for rounding in [Rounding::Nearest, Rounding::Stochastic] {
            let mut rng_ref = Rng::new(42);
            let (signs, codes, decoded) =
                exact_encode_reference(&t, fmt, scaling, rounding, Some(&mut rng_ref));
            for workers in [2usize, 3, 7, 8] {
                let mut scratch = QuantScratch::default();
                let mut rt = t.clone();
                let mut rng_rt = Rng::new(42);
                kernels::quantize_rows_into_rounded(
                    &mut rt.data,
                    rows,
                    cols,
                    fmt,
                    scaling,
                    rounding,
                    Some(&mut rng_rt),
                    workers,
                    &mut scratch,
                );
                assert!(
                    rt.data.iter().zip(decoded.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{scaling:?} {rounding:?} @ {workers} workers: roundtrip diverged"
                );
                let scales = group_scales(&t, fmt, scaling);
                let mut got_s = vec![0i8; t.len()];
                let mut got_c = vec![0u32; t.len()];
                let mut rng_enc = Rng::new(42);
                kernels::encode_rows_into(
                    &mut got_s,
                    &mut got_c,
                    &t.data,
                    rows,
                    cols,
                    fmt,
                    scaling,
                    rounding,
                    Some(&mut rng_enc),
                    &scales,
                    workers,
                );
                assert!(
                    got_s == signs && got_c == codes,
                    "{scaling:?} {rounding:?} @ {workers} workers: planes diverged"
                );
            }
        }
    }
}

#[test]
fn parallel_gemm_bit_identical_property() {
    // Random shapes x random worker counts: the row-partitioned GEMMs
    // must equal the sequential kernels bit for bit (the contract the
    // parallel training engine rests on).
    property(40, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 160);
        let n = g.usize_in(1, 40);
        let workers = g.usize_in(2, 9);
        let mut rng = Rng::new(0xBEEF ^ g.case as u64);
        let a = Tensor::randn(m, k, 1.0, &mut rng);
        let b = Tensor::randn(k, n, 1.0, &mut rng);
        let c = Tensor::randn(m, n, 1.0, &mut rng);
        assert_eq!(a.matmul(&b).data, a.matmul_p(&b, workers).data, "matmul {m}x{k}x{n}");
        assert_eq!(
            a.t_matmul(&c).data,
            a.t_matmul_p(&c, workers).data,
            "t_matmul {m}x{k}x{n}"
        );
        assert_eq!(
            c.matmul_t(&b).data,
            c.matmul_t_p(&b, workers).data,
            "matmul_t {m}x{k}x{n}"
        );
    });
}

#[test]
fn lns_exec_matmul_bounded_and_bit_identical_across_workers_property() {
    // The integer-domain training tier (`lns::exec`): at random shapes
    // and every conversion mode, the GEMM stays within the Mitchell/
    // hybrid envelope of the exact f32 product of the quantized
    // operands, and both outputs and op counts are bit-identical at
    // every worker count.
    use lns_madam::lns::convert::mitchell_bound;
    use lns_madam::lns::exec::lns_matmul_into;
    use lns_madam::lns::{quantize_tensor, ConvertMode, ExecScratch, LnsExecCfg, OpCounts};

    let fmt = LnsFormat::new(8, 8);
    let modes: [(ConvertMode, u32); 5] = [
        (ConvertMode::Reference, 1),
        (ConvertMode::ExactLut, 1),
        (ConvertMode::Hybrid { lut_bits: 2 }, 2),
        (ConvertMode::Hybrid { lut_bits: 1 }, 4),
        (ConvertMode::Mitchell, 8),
    ];
    property(25, |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 96);
        let n = g.usize_in(1, 24);
        let mut rng = Rng::new(0xE1EC ^ g.case as u64);
        let a = Tensor::randn(m, k, 1.0, &mut rng);
        let b = Tensor::randn(k, n, 1.0, &mut rng);
        let aq = quantize_tensor(&a, fmt, Scaling::PerTensor);
        let bq = quantize_tensor(&b, fmt, Scaling::PerTensor);
        let reference = aq.matmul(&bq);
        let abs_ref = aq.map(f32::abs).matmul(&bq.map(f32::abs));
        let slack = 1e-3 * reference.abs_max().max(1.0);
        let (mode, span) = modes[g.usize_in(0, modes.len() - 1)];
        let cfg = LnsExecCfg { fmt, convert: mode, acc_bits: 24 };
        let bound = mitchell_bound(fmt.gamma, span) as f32;

        let run = |workers: usize| {
            let mut out = vec![0.0f32; m * n];
            let mut scratch = ExecScratch::new();
            let mut counts = OpCounts::default();
            lns_matmul_into(
                &mut out,
                &a.data,
                &b.data,
                m,
                k,
                n,
                cfg,
                workers,
                &mut scratch,
                &mut counts,
            );
            (out, counts)
        };
        let (want, want_counts) = run(1);
        lns_madam::prop_assert!(
            g,
            want_counts.total_macs() == (m * k * n) as u64,
            "{mode:?} {m}x{k}x{n}: MAC total {} != {}",
            want_counts.total_macs(),
            m * k * n
        );
        for i in 0..want.len() {
            let err = (want[i] - reference.data[i]).abs();
            let budget = bound * abs_ref.data[i] + slack;
            lns_madam::prop_assert!(
                g,
                err <= budget,
                "{mode:?} {m}x{k}x{n}: elem {i} err {err} > budget {budget}"
            );
        }
        for workers in [2usize, 4, 8] {
            let (got, counts) = run(workers);
            lns_madam::prop_assert!(
                g,
                got.iter().zip(want.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{mode:?} {m}x{k}x{n} @ {workers} workers: outputs diverged"
            );
            lns_madam::prop_assert!(
                g,
                counts == want_counts,
                "{mode:?} {m}x{k}x{n} @ {workers} workers: op counts diverged"
            );
        }
    });
}

#[test]
fn simd_gemm_off_auto_bit_identical_property() {
    // ISSUE-7: the AVX2 band kernels are bitwise replays of the scalar
    // microkernels (mul+add intrinsics, per-lane IEEE chains), so
    // toggling the process-wide mode between Off and Auto must never
    // change a single output bit. Shapes deliberately straddle the
    // 8-lane vector width, the 16-lane panel, and the TILE_K depth;
    // sparsity exercises the zero-skip path. Off <-> Auto toggling is
    // race-safe under the concurrent test harness for the same reason:
    // a racing test observing either mode sees the same numbers.
    use lns_madam::util::simd::{set_mode, SimdMode};
    let shapes: [(usize, usize, usize); 6] =
        [(1, 1, 1), (3, 7, 9), (8, 16, 16), (9, 127, 17), (5, 128, 33), (11, 129, 40)];
    property(12, |g| {
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            let workers = g.usize_in(1, 5);
            let mut rng = Rng::new(0x51D ^ ((g.case * 8 + si) as u64));
            let mut a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let mut c = Tensor::randn(m, n, 1.0, &mut rng);
            let every = 2 + g.usize_in(0, 3);
            for (i, v) in a.data.iter_mut().enumerate() {
                if i % every == 0 {
                    *v = 0.0;
                }
            }
            for (i, v) in c.data.iter_mut().enumerate() {
                if i % every == 1 {
                    *v = 0.0;
                }
            }
            let bits = |t: &Tensor| t.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            set_mode(SimdMode::Off).unwrap();
            let want_ab = bits(&a.matmul_p(&b, workers));
            let want_ac = bits(&a.t_matmul_p(&c, workers));
            let want_cb = bits(&c.matmul_t_p(&b, workers));
            set_mode(SimdMode::Auto).unwrap();
            assert_eq!(want_ab, bits(&a.matmul_p(&b, workers)), "matmul {m}x{k}x{n} @ {workers}");
            assert_eq!(
                want_ac,
                bits(&a.t_matmul_p(&c, workers)),
                "t_matmul {m}x{k}x{n} @ {workers}"
            );
            assert_eq!(
                want_cb,
                bits(&c.matmul_t_p(&b, workers)),
                "matmul_t {m}x{k}x{n} @ {workers}"
            );
        }
    });
}

#[test]
fn simd_quantizer_off_auto_bit_identical_property() {
    // The AVX2 quantizer span kernels vectorize only the fast nearest
    // path and bail to the scalar per-lane closure for near-tie,
    // non-finite, and zero lanes — so Off vs Auto is bitwise across
    // formats (fast-path-safe and not), scalings, and rounding modes,
    // including the planes the encode kernel writes.
    use lns_madam::util::simd::{set_mode, SimdMode};
    let formats = [
        LnsFormat::new(8, 8),
        LnsFormat::new(8, 32),
        LnsFormat::new(6, 4),
        LnsFormat::new(12, 128),
    ];
    for fmt in formats {
        for scaling in [Scaling::PerTensor, Scaling::PerRow, Scaling::PerCol] {
            for rounding in [Rounding::Nearest, Rounding::Stochastic] {
                property(8, |g| {
                    let rows = g.usize_in(1, 6);
                    let cols = g.usize_in(1, 40); // spans straddle the 8-lane width
                    let t =
                        Tensor::from_vec(rows, cols, quantizer_stress_data(g, rows * cols, fmt));
                    let seed = 0x51D0 ^ g.case as u64;
                    let workers = g.usize_in(1, 4);
                    let run = || {
                        let mut scratch = QuantScratch::default();
                        let mut rt = t.clone();
                        let mut rng_rt = Rng::new(seed);
                        kernels::quantize_rows_into_rounded(
                            &mut rt.data,
                            rows,
                            cols,
                            fmt,
                            scaling,
                            rounding,
                            Some(&mut rng_rt),
                            workers,
                            &mut scratch,
                        );
                        let scales = group_scales(&t, fmt, scaling);
                        let mut signs = vec![0i8; t.len()];
                        let mut codes = vec![0u32; t.len()];
                        let mut rng_enc = Rng::new(seed);
                        kernels::encode_rows_into(
                            &mut signs,
                            &mut codes,
                            &t.data,
                            rows,
                            cols,
                            fmt,
                            scaling,
                            rounding,
                            Some(&mut rng_enc),
                            &scales,
                            workers,
                        );
                        (rt, signs, codes)
                    };
                    set_mode(SimdMode::Off).unwrap();
                    let want = run();
                    set_mode(SimdMode::Auto).unwrap();
                    let got = run();
                    let bits = |t: &Tensor| t.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                    lns_madam::prop_assert!(
                        g,
                        bits(&want.0) == bits(&got.0) && want.1 == got.1 && want.2 == got.2,
                        "{fmt:?} {scaling:?} {rounding:?}: Off vs Auto diverged"
                    );
                });
            }
        }
    }
}

#[test]
fn simd_fma_tier_value_close_property() {
    // The Force-only FMA GEMM tier fuses each multiply-add into one
    // rounding, so it is NOT bitwise — but every element must stay
    // within a tight relative envelope of the scalar result, scaled by
    // the |A|@|B| magnitude sum (the usual reassociation bound). The
    // tier is reached through the explicit `*_fma` hooks, which never
    // touch the process-wide mode. `None` (no AVX2+FMA host) passes
    // vacuously: the scalar fallback is the tier on such machines.
    property(30, |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 160);
        let n = g.usize_in(1, 24);
        let mut rng = Rng::new(0xF3A ^ g.case as u64);
        let a = Tensor::randn(m, k, 1.0, &mut rng);
        let b = Tensor::randn(k, n, 1.0, &mut rng);
        let c = Tensor::randn(m, n, 1.0, &mut rng);
        let check = |got: Option<Tensor>, want: &Tensor, abs: &Tensor, tag: &str| {
            let Some(got) = got else { return };
            assert_eq!(got.rows, want.rows, "{tag}: shape");
            for i in 0..want.data.len() {
                let err = (got.data[i] - want.data[i]).abs();
                // ~2*k*eps relative to the magnitude sum covers any
                // reassociation of a k<=160 chain with lots of slack.
                let budget = 1e-4 * abs.data[i].max(1e-10);
                assert!(err <= budget, "{tag} {m}x{k}x{n}: elem {i} err {err} > {budget}");
            }
        };
        let abs_ab = a.map(f32::abs).matmul(&b.map(f32::abs));
        check(a.matmul_fma(&b), &a.matmul(&b), &abs_ab, "matmul_fma");
        let abs_ac = a.map(f32::abs).t_matmul(&c.map(f32::abs));
        check(a.t_matmul_fma(&c), &a.t_matmul(&c), &abs_ac, "t_matmul_fma");
        let abs_cb = c.map(f32::abs).matmul_t(&b.map(f32::abs));
        check(c.matmul_t_fma(&b), &c.matmul_t(&b), &abs_cb, "matmul_t_fma");
    });
}

#[test]
fn packed_gemm_bit_identical_to_reference_property() {
    // ISSUE-5: the packed register-blocked microkernels replay the
    // pre-packing tiled kernels' exact per-element FP op sequence, so
    // equality against the retained `*_unpacked` reference kernels is
    // bitwise — at random shapes, random sparsity (the zero-skip
    // path), and random worker counts.
    property(40, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 160);
        let n = g.usize_in(1, 40);
        let workers = g.usize_in(1, 9);
        let mut rng = Rng::new(0xD1CE ^ g.case as u64);
        let sparsify = |t: &mut Tensor, every: usize| {
            for (i, v) in t.data.iter_mut().enumerate() {
                if i % every == 0 {
                    *v = 0.0;
                }
            }
        };
        let mut a = Tensor::randn(m, k, 1.0, &mut rng);
        let b = Tensor::randn(k, n, 1.0, &mut rng);
        let mut c = Tensor::randn(m, n, 1.0, &mut rng);
        sparsify(&mut a, 2 + g.usize_in(0, 3));
        sparsify(&mut c, 2 + g.usize_in(0, 3));
        let bits = |t: &Tensor| t.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&a.matmul_p(&b, workers)),
            bits(&a.matmul_unpacked(&b)),
            "matmul {m}x{k}x{n} @ {workers}"
        );
        assert_eq!(
            bits(&a.t_matmul_p(&c, workers)),
            bits(&a.t_matmul_unpacked(&c)),
            "t_matmul {m}x{k}x{n} @ {workers}"
        );
        assert_eq!(
            bits(&c.matmul_t_p(&b, workers)),
            bits(&c.matmul_t_unpacked(&b)),
            "matmul_t {m}x{k}x{n} @ {workers}"
        );
    });
}

#[test]
fn ddp_wire_reduce_matches_fake_quant_and_tree_order_is_bit_identical() {
    // The Q_G wire contract (ISSUE 9): encode -> reduce -> decode of
    // gradient-shaped shard tensors (zeros, subnormals, +-extreme
    // magnitudes, both roundings) matches applying the Q_G fake-quant
    // then reducing in f32, within the Lemma-1 bound — the only
    // difference is the wire's flush-to-zero of the bottom code, whose
    // per-shard cost is at most one `scale`. And the whole pipeline is
    // a pure function of the shard tensors: re-running it (as a
    // different replica grouping would) reproduces every bit.
    use lns_madam::coordinator::ddp::{
        decode_wire_into, encode_wire_rounded, tree_reduce_into, WireKind, WireScratch,
    };
    for fmt in [LnsFormat::new(8, 8), LnsFormat::new(8, 4), LnsFormat::new(12, 128)] {
        let kind = WireKind::Lns(fmt);
        for rounding in [Rounding::Nearest, Rounding::Stochastic] {
            // Nearest stays within Lemma 1; stochastic may take the far
            // neighbor, doubling the log-step.
            let bound = match rounding {
                Rounding::Nearest => fmt.max_rel_error(),
                Rounding::Stochastic => (1.0 / fmt.gamma as f64).exp2() - 1.0,
            } as f32;
            property(50, |g| {
                let shards = [1usize, 2, 4, 8][g.usize_in(0, 3)];
                let len = g.usize_in(1, 40);
                let bufs: Vec<Vec<f32>> = (0..shards)
                    .map(|_| {
                        (0..len)
                            .map(|_| {
                                let sign = if g.bool() { -1.0f32 } else { 1.0 };
                                match g.usize_in(0, 9) {
                                    0 => 0.0,
                                    // Subnormals: must flush cleanly, never panic.
                                    1 => sign * f32::from_bits(g.usize_in(1, 0x7f_ffff) as u32),
                                    // +-extreme magnitudes near f32::MAX.
                                    2 => sign * 3.0e38,
                                    3..=5 => g.normal_f32(),
                                    _ => g.lns_value(),
                                }
                            })
                            .collect()
                    })
                    .collect();
                let seed = 0xD0D0 ^ g.case as u64;

                // Wire path: per-shard encode (the "send"), root decode
                // in shard order, fixed-tree reduce, exact 1/L rescale.
                let run_wire = || {
                    let mut ws = WireScratch::default();
                    let mut rng = Rng::new(seed);
                    let wires: Vec<_> = bufs
                        .iter()
                        .map(|b| encode_wire_rounded(b, kind, rounding, Some(&mut rng), &mut ws))
                        .collect();
                    let decoded: Vec<Vec<f32>> = wires
                        .iter()
                        .map(|w| {
                            let mut out = vec![0.0f32; len];
                            decode_wire_into(&mut out, w, kind);
                            out
                        })
                        .collect();
                    (wires, decoded)
                };
                let (wires, decoded) = run_wire();

                // Reference: the same Q_G fake-quant kernel (identically
                // seeded, so stochastic draws match), reduced in f32.
                let mut scratch = QuantScratch::default();
                let mut rng = Rng::new(seed);
                let fq: Vec<Vec<f32>> = bufs
                    .iter()
                    .map(|b| {
                        let mut d = b.clone();
                        kernels::quantize_rows_into_rounded(
                            &mut d,
                            1,
                            len,
                            fmt,
                            Scaling::PerTensor,
                            rounding,
                            Some(&mut rng),
                            1,
                            &mut scratch,
                        );
                        d
                    })
                    .collect();

                // Elementwise: the wire is the fake-quant value, except
                // the bottom code flushes to exact zero (|x| <= about
                // one scale there).
                for ((buf, dec), (w, q)) in
                    bufs.iter().zip(decoded.iter()).zip(wires.iter().zip(fq.iter()))
                {
                    for ((&x, &d), &qv) in buf.iter().zip(dec.iter()).zip(q.iter()) {
                        if d == 0.0 {
                            // scale == 0.0 happens when the shard absmax
                            // is itself a tiny subnormal (the scale
                            // underflows); everything flushes there.
                            assert!(
                                w.scale == 0.0 || x.abs() <= w.scale * (1.0 + bound) * 1.01,
                                "{fmt:?}/{rounding:?}: flushed non-bottom value {x} (scale {})",
                                w.scale
                            );
                        } else {
                            let rel = ((d - x) / x).abs();
                            assert!(
                                rel <= bound * 1.01,
                                "{fmt:?}/{rounding:?}: wire {x} -> {d}, rel {rel} > {bound}"
                            );
                            assert!(
                                (d - qv).abs() <= 2e-6 * qv.abs().max(1e-30),
                                "{fmt:?}/{rounding:?}: wire {d} vs fake-quant {qv}"
                            );
                        }
                    }
                }

                // Reduced means agree within the accumulated FTZ slack.
                let inv = 1.0 / shards as f32;
                let mut a = decoded.clone();
                let mut b = fq.clone();
                tree_reduce_into(&mut a);
                tree_reduce_into(&mut b);
                let slack: f32 =
                    wires.iter().map(|w| w.scale).sum::<f32>() * inv * (1.0 + bound) * 1.01;
                for (x, y) in a[0].iter().zip(b[0].iter()) {
                    let (x, y) = (x * inv, y * inv);
                    assert!(
                        (x - y).abs() <= slack + 2e-6 * y.abs(),
                        "{fmt:?}/{rounding:?}: reduced {x} vs fake-quant {y} (slack {slack})"
                    );
                }

                // Fixed tree order: the pipeline is a pure function of
                // the shard tensors, so a second run (any replica
                // grouping) reproduces the reduced gradient bitwise.
                let (_, decoded2) = run_wire();
                let mut c = decoded2;
                tree_reduce_into(&mut c);
                for (x, y) in a[0].iter().zip(c[0].iter()) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{fmt:?}/{rounding:?}: wire reduce is not deterministic"
                    );
                }
            });
        }
    }
}
