//! Quickstart: the LNS format end to end in five minutes.
//!
//!   cargo run --release --example quickstart
//!
//! 1. Quantize a tensor through the multi-base LNS in pure rust.
//! 2. Run the same Q_log as the AOT-compiled Pallas kernel via PJRT
//!    and check they agree bit-for-bit.
//! 3. Multiply two matrices on the bit-faithful Fig. 6 datapath.
//! 4. One Madam step on LNS weights, next to the SGD step it replaces.

use anyhow::Result;
use lns_madam::lns::{
    encode_tensor, quantize_tensor, ConvertMode, LnsFormat, MacConfig, Rounding, Scaling,
    VectorMacUnit,
};
use lns_madam::optim::{Madam, Optimizer, QuantizedUpdate, Sgd, UpdateQuantizer};
use lns_madam::runtime::{artifacts_available, lit_f32, lit_scalar, to_vec_f32, Manifest, Runtime};
use lns_madam::util::rng::Rng;
use lns_madam::util::tensor::Tensor;
use std::path::Path;

fn main() -> Result<()> {
    let fmt = LnsFormat::PAPER8; // B = 8 bits, gamma = 8
    println!("LNS format: {} bits, gamma {}", fmt.bits, fmt.gamma);
    println!(
        "  dynamic range (0, {:.1}) octaves, max relative error {:.3}%",
        fmt.dynamic_range_log2(),
        fmt.max_rel_error() * 100.0
    );

    // --- 1. quantize a tensor -------------------------------------------
    let mut rng = Rng::new(42);
    let x = Tensor::randn(4, 4, 1.0, &mut rng);
    let q = quantize_tensor(&x, fmt, Scaling::PerTensor);
    println!("\nQ_log round-trip (first row):");
    for c in 0..4 {
        println!("  {:+.6} -> {:+.6}", x.at(0, c), q.at(0, c));
    }

    // --- 2. same computation via the AOT Pallas kernel -------------------
    let artifacts = Path::new("artifacts");
    if artifacts_available(artifacts) {
        let runtime = Runtime::cpu()?;
        let manifest = Manifest::load(artifacts)?;
        let exe = runtime.load(&manifest, "kernel_quantize")?;
        let mut big = Tensor::randn(1024, 1024, 1.0, &mut rng);
        let outs = exe.run(&[
            lit_f32(&[1024, 1024], &big.data)?,
            lit_scalar(fmt.gamma as f32),
            lit_scalar(fmt.max_code() as f32),
        ])?;
        let kernel_q = to_vec_f32(&outs[0])?;
        lns_madam::lns::quant::quantize_slice(&mut big.data, fmt);
        // Bit parity up to f32 log2 rounding ties: count elements whose
        // codes disagree (must be a vanishing fraction, each by 1 code).
        let gap = fmt.gap_factor() as f32;
        let mut mismatches = 0usize;
        for (a, b) in big.data.iter().zip(kernel_q.iter()) {
            if (a - b).abs() > 1e-6 * a.abs().max(1e-12) {
                mismatches += 1;
                assert!(
                    (a / b).abs().max((b / a).abs()) < gap * 1.0001,
                    "codes differ by more than one step: {a} vs {b}"
                );
            }
        }
        println!(
            "\nPallas kernel vs rust Q_log on 1M elements: {mismatches} rounding-tie mismatches ({:.4}%)",
            mismatches as f64 / big.data.len() as f64 * 100.0
        );
        assert!((mismatches as f64 / big.data.len() as f64) < 1e-3);
    } else {
        println!("\n(skip PJRT check: run `make artifacts` first)");
    }

    // --- 3. the Fig. 6 datapath ------------------------------------------
    let a = Tensor::randn(8, 32, 1.0, &mut rng);
    let b = Tensor::randn(32, 8, 1.0, &mut rng);
    let ea = encode_tensor(&a, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let eb = encode_tensor(&b, fmt, Scaling::PerTensor, Rounding::Nearest, None);
    let mut mac = VectorMacUnit::new(MacConfig::paper());
    let c = mac.matmul(&ea, &eb);
    let exact = quantize_tensor(&a, fmt, Scaling::PerTensor)
        .matmul(&quantize_tensor(&b, fmt, Scaling::PerTensor));
    let rel = c
        .data
        .iter()
        .zip(exact.data.iter())
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max)
        / exact.abs_max();
    println!(
        "\nLNS vector-MAC datapath: {} MACs, {} LUT multiplies, rel err {rel:.2e}",
        mac.counts.total_macs(),
        mac.counts.lut_muls
    );

    // Hybrid Mitchell approximation shrinks the LUT 8x:
    let mut cfg = MacConfig::paper();
    cfg.convert = ConvertMode::Mitchell;
    let mut mac1 = VectorMacUnit::new(cfg);
    let c1 = mac1.matmul(&ea, &eb);
    let rel1 = c1
        .data
        .iter()
        .zip(exact.data.iter())
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max)
        / exact.abs_max();
    println!("  with Mitchell approximation (LUT=1): rel err {rel1:.2e}");

    // --- 4. Madam vs SGD under the quantized weight update ---------------
    let qu = UpdateQuantizer::lns_matched(8);
    let mut w_sgd = vec![64.0f32, 1.0, 128.0];
    let mut w_mad = w_sgd.clone();
    let mut rng2 = Rng::new(0);
    qu.apply(&mut w_sgd, &mut rng2);
    qu.apply(&mut w_mad, &mut rng2);
    let mut sgd = QuantizedUpdate::new(Sgd::with(1e-3, 0.0, 0.0), qu.clone());
    let mut madam = QuantizedUpdate::new(Madam::new(2f32.powi(-4)), qu);
    for _ in 0..20 {
        sgd.step(0, &mut w_sgd, &[1.0, 1.0, 0.0]);
        madam.step(0, &mut w_mad, &[1.0, 1.0, 0.0]);
    }
    println!("\n20 quantized-update steps, grad = 1 on w0 (64.0) and w1 (1.0):");
    println!("  SGD   -> {w_sgd:?}   (large weight frozen: sub-gap updates swallowed)");
    println!("  Madam -> {w_mad:?}   (both weights move proportionally)");
    println!("\nquickstart OK");
    Ok(())
}
