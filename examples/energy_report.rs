//! Full energy report: regenerates the paper's energy results from the
//! calibrated PE model — Table 8 / Fig. 2 (per-iteration energy), Fig. 8
//! (PE breakdown by format), Fig. 9 (LNS datapath components), Fig. 10
//! (GPT 1B–1T scaling), and the Table 10 energy row (LUT sweep).
//!
//!   cargo run --release --example energy_report

use lns_madam::hw::{gpt_workloads, table8_workloads, EnergyModel, PeFormat};
use lns_madam::lns::{ConvertMode, LnsFormat};
use lns_madam::util::bench::print_table;

fn main() {
    let em = EnergyModel::paper();
    let formats = [
        PeFormat::Lns(ConvertMode::ExactLut),
        PeFormat::Fp8,
        PeFormat::Fp16,
        PeFormat::Fp32,
    ];

    // ---- Table 8 / Fig. 2 -------------------------------------------------
    let mut rows = Vec::new();
    for w in table8_workloads() {
        let mut row = vec![w.name.clone()];
        for f in formats {
            row.push(format!("{:.2}", em.workload_mj(f, w.total_macs())));
        }
        rows.push(row);
    }
    print_table(
        "Table 8 / Fig. 2: per-iteration training energy (mJ)",
        &["Model", "LNS", "FP8", "FP16", "FP32"],
        &rows,
    );
    let lns = em.pe_mac_fj(PeFormat::Lns(ConvertMode::ExactLut));
    println!(
        "paper anchors: LNS is 2.2x/4.6x/11x vs FP8/FP16/FP32; model gives {:.1}x/{:.1}x/{:.1}x",
        em.pe_mac_fj(PeFormat::Fp8) / lns,
        em.pe_mac_fj(PeFormat::Fp16) / lns,
        em.pe_mac_fj(PeFormat::Fp32) / lns,
    );
    println!(
        "energy saved vs FP32: {:.1}% (paper: >90%)",
        (1.0 - lns / em.pe_mac_fj(PeFormat::Fp32)) * 100.0
    );

    // ---- Fig. 8: PE breakdown ----------------------------------------------
    let mut rows = Vec::new();
    for f in formats {
        let b = em.pe_breakdown(f);
        let total = b.total();
        let mut row = vec![b.label.clone(), format!("{total:.1}")];
        for (name, v) in &b.parts {
            row.push(format!("{name} {:.0}%", v / total * 100.0));
        }
        rows.push(row);
    }
    print_table(
        "Fig. 8: PE energy breakdown per MAC (fJ, % by component)",
        &["format", "total fJ", "c1", "c2", "c3", "c4", "c5"],
        &rows,
    );

    // ---- Fig. 9: LNS datapath components ------------------------------------
    let b = em.lns_datapath_breakdown(LnsFormat::PAPER8, ConvertMode::ExactLut);
    let rows: Vec<Vec<String>> = b
        .parts
        .iter()
        .map(|(n, v)| vec![n.clone(), format!("{v:.2}"), format!("{:.1}%", v / b.total() * 100.0)])
        .collect();
    print_table(
        "Fig. 9: LNS datapath energy per MAC by component",
        &["component", "fJ", "share"],
        &rows,
    );

    // ---- Table 10 energy row -------------------------------------------------
    let paper = [12.29, 14.71, 17.24, 19.02];
    let modes = [
        ConvertMode::Mitchell,
        ConvertMode::Hybrid { lut_bits: 1 },
        ConvertMode::Hybrid { lut_bits: 2 },
        ConvertMode::ExactLut,
    ];
    let rows: Vec<Vec<String>> = modes
        .iter()
        .zip(paper.iter())
        .map(|(m, p)| {
            vec![
                format!("LUT={}", m.lut_entries(LnsFormat::PAPER8)),
                format!("{:.2}", em.datapath_mac_fj(PeFormat::Lns(*m))),
                format!("{p:.2}"),
            ]
        })
        .collect();
    print_table(
        "Table 10 energy row: conversion approximation (fJ/op)",
        &["config", "model", "paper"],
        &rows,
    );

    // ---- Fig. 10: GPT scaling --------------------------------------------------
    let mut rows = Vec::new();
    for w in gpt_workloads() {
        let mut row = vec![w.name.clone()];
        for f in formats {
            row.push(format!("{:.1}", em.workload_mj(f, w.total_macs()) / 1e3)); // J
        }
        rows.push(row);
    }
    print_table(
        "Fig. 10: per-iteration energy across GPT scales (J)",
        &["Model", "LNS", "FP8", "FP16", "FP32"],
        &rows,
    );
}
