//! End-to-end LNS-Madam training of the MLP on synthetic classification,
//! with an FP32+SGD reference run for comparison — the "Table 4 row" of
//! the reproduction at laptop scale.
//!
//! Runs on the PJRT backend when artifacts are available and on the
//! pure-Rust native backend otherwise (`--backend` in the CLI picks
//! explicitly).
//!
//!   cargo run --release --example train_mlp -- [steps] [csv_prefix]

use anyhow::Result;
use lns_madam::coordinator::{OptKind, TrainConfig, Trainer};

fn run(format: &str, opt: OptKind, steps: usize, log: &str) -> Result<(f64, Option<f64>)> {
    // LNS runs use the quantized weight update at 16-bit; the FP32
    // baseline keeps the conventional full-precision update.
    let cfg = TrainConfig {
        model: "mlp".into(),
        format: format.into(),
        optimizer: opt,
        lr: opt.default_lr(),
        steps,
        eval_every: (steps / 4).max(1),
        log_path: log.to_string(),
        qu_bits: if format == "lns" { 16 } else { 0 },
        ..TrainConfig::default()
    };
    println!("\n=== {} + {} ({} steps) ===", format, opt.name(), steps);
    let mut trainer = Trainer::new(cfg)?;
    println!("backend: {}", trainer.backend_name());
    trainer.run()?;
    Ok((trainer.final_loss(10), trainer.final_eval_acc()))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let prefix = args.get(1).cloned().unwrap_or_else(|| "train_mlp".into());

    let (lns_loss, lns_acc) = run(
        "lns",
        OptKind::Madam,
        steps,
        &format!("{prefix}_lns_madam.csv"),
    )?;
    let (fp8_loss, fp8_acc) = run(
        "fp8",
        OptKind::Sgd,
        steps,
        &format!("{prefix}_fp8_sgd.csv"),
    )?;
    let (fp32_loss, fp32_acc) = run(
        "fp32",
        OptKind::Sgd,
        steps,
        &format!("{prefix}_fp32_sgd.csv"),
    )?;

    println!("\n=== summary (final tail-10 train loss / eval acc) ===");
    let fmt_acc = |a: Option<f64>| a.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into());
    println!("  LNS-Madam 8-bit : loss {lns_loss:.4}  acc {}", fmt_acc(lns_acc));
    println!("  FP8 + SGD       : loss {fp8_loss:.4}  acc {}", fmt_acc(fp8_acc));
    println!("  FP32 + SGD      : loss {fp32_loss:.4}  acc {}", fmt_acc(fp32_acc));
    println!("\nloss curves: {prefix}_*.csv");
    Ok(())
}
