//! Quantization-error study (Fig. 1 + Fig. 4): measure E r_t for GD,
//! multiplicative, and sign-multiplicative updates across learning rates
//! and base factors, next to the Theorem 1/2 + Lemma 1 bounds.
//!
//!   cargo run --release --example quant_error_study [-- --fig1]

use lns_madam::optim::error::{
    bound_gd, bound_mul, bound_sign_mul, fig4_sweep, quant_error, Learner,
};
use lns_madam::util::bench::print_table;
use lns_madam::util::rng::Rng;

fn fig1_illustration() {
    // Fig. 1: same gradient applied at a small and a large weight; GD's
    // step is swallowed by the widening gap, Madam's scales with it.
    println!("\n=== Fig. 1 illustration (gamma = 8, 8-bit codes) ===");
    let fmt = lns_madam::lns::LnsFormat::PAPER8;
    let scale = fmt.scale_for_absmax(128.0);
    for w0 in [0.5f32, 4.0, 32.0] {
        let gap = w0 * (fmt.gap_factor() as f32 - 1.0);
        let gd_step = 0.05f32; // eta * g
        let madam_step = w0 * (2f32.powf(0.05) - 1.0); // eta * g in log space
        let snap = |x: f32| fmt.decode(fmt.encode(x, scale), scale);
        println!(
            "  w = {w0:6.2}: gap {gap:7.3}  | GD step {gd_step:5.3} -> moved {:7.3} | Madam step {madam_step:7.3} -> moved {:7.3}",
            (snap(w0 - gd_step) - snap(w0)).abs(),
            (snap(w0 - madam_step) - snap(w0)).abs(),
        );
    }
}

fn main() {
    let fig1 = std::env::args().any(|a| a == "--fig1");
    if fig1 {
        fig1_illustration();
        return;
    }

    // Fig. 4 protocol: ResNet-scale dimension, eta sweep at gamma=2^10,
    // gamma sweep at eta=2^-6.
    let etas: Vec<f64> = (4..=10).map(|k| 2f64.powi(-k)).collect();
    let gammas: Vec<f64> = (3..=12).map(|k| 2f64.powi(k)).collect();
    let points = fig4_sweep(65_536, &etas, &gammas, 0);

    let mut rows = Vec::new();
    for p in &points {
        rows.push(vec![
            p.learner.name().to_string(),
            format!("2^{:.0}", p.eta.log2()),
            format!("2^{:.0}", p.gamma.log2()),
            format!("{:.4e}", p.error),
            format!("{:.4e}", p.bound),
            if p.error <= p.bound { "ok".into() } else { "VIOLATED".into() },
        ]);
    }
    print_table(
        "Fig. 4: quantization error r_t vs theory bounds (d = 65536)",
        &["learner", "eta", "gamma", "E r_t", "bound", "check"],
        &rows,
    );

    // Summary ratio at the paper's operating point.
    let mut rng = Rng::new(1);
    let dim = 65_536;
    let w: Vec<f64> = (0..dim)
        .map(|_| {
            let s = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
            s * (rng.normal() * 1.5).exp2()
        })
        .collect();
    // Lognormal gradient magnitudes around 1e-3 (Chmiel et al. 2021).
    let g: Vec<f64> = (0..dim)
        .map(|_| {
            let s = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
            s * (rng.normal() * 1.5 - 10.0).exp2()
        })
        .collect();
    let (eta, gamma) = (2f64.powi(-6), 2f64.powi(10));
    let e_gd = quant_error(Learner::Gd, &w, &g, eta, gamma, 10, &mut rng);
    let e_mul = quant_error(Learner::Mul, &w, &g, eta, gamma, 10, &mut rng);
    let e_sgn = quant_error(Learner::SignMul, &w, &g, eta, gamma, 10, &mut rng);
    println!("\nAt eta=2^-6, gamma=2^10 (the Fig. 4 operating point):");
    println!("  GD      E r = {e_gd:.4e}   (bound {:.4e})", bound_gd(&w, &g, eta, gamma));
    println!("  MUL     E r = {e_mul:.4e}   (bound {:.4e})", bound_mul(&g, eta, gamma));
    println!("  signMUL E r = {e_sgn:.4e}   (bound {:.4e})", bound_sign_mul(dim, eta, gamma));
    println!("  GD / MUL error ratio: {:.1}x", e_gd / e_mul);
}
