//! End-to-end driver: train a language model with LNS-Madam and log the
//! loss curve. With artifacts this runs the full three-layer stack
//! (Pallas kernels -> JAX HLO -> PJRT -> rust Madam updates); without
//! them the backend-generic trainer drives the native char-LM mirror,
//! so the example works offline (EXPERIMENTS.md §E2E).
//!
//!   cargo run --release --example train_transformer -- \
//!       [--model tfm_tiny|tfm_small|tfm_100m] [--steps N] [--format lns|fp8|fp32]
//!       [--optimizer madam|sgd|adamw] [--lr X] [--csv path]
//!       [--backend auto|native|pjrt]
//!
//! tfm_small / tfm_100m on PJRT need `make artifacts-full` / `-100m`.

use anyhow::{bail, Result};
use lns_madam::backend::native::{builtin_presets, PresetSpec};
use lns_madam::backend::BackendKind;
use lns_madam::coordinator::{OptKind, TrainConfig, Trainer};
use lns_madam::hw::workload::transformer_macs;
use lns_madam::hw::{EnergyModel, PeFormat};
use lns_madam::lns::ConvertMode;
use lns_madam::runtime::{artifacts_available, Manifest};
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = TrainConfig {
        model: "tfm_tiny".into(),
        steps: 300,
        eval_every: 25,
        ..TrainConfig::default()
    };
    let mut csv = "train_transformer.csv".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--model" => cfg.model = args[i + 1].clone(),
            "--steps" => cfg.steps = args[i + 1].parse()?,
            "--format" => cfg.format = args[i + 1].clone(),
            "--optimizer" => {
                cfg.optimizer = OptKind::parse(&args[i + 1])?;
                cfg.lr = cfg.optimizer.default_lr();
            }
            "--lr" => cfg.lr = args[i + 1].parse()?,
            "--csv" => csv = args[i + 1].clone(),
            "--backend" => cfg.backend = BackendKind::parse(&args[i + 1])?,
            other => bail!("unknown flag {other}"),
        }
        i += 2;
    }
    cfg.log_path = csv.clone();
    cfg.qu_bits = if cfg.format == "lns" { 16 } else { 0 };

    // Model dims for the energy report: manifest metadata when lowered,
    // the matching native preset's values otherwise.
    let preset = builtin_presets().iter().find(|p| p.name == cfg.model);
    let (pd, pff, pv, pt, pb) = match preset {
        Some(p) => match p.spec {
            PresetSpec::CharLm { vocab, seq, d_model, d_ff } => {
                (d_model, d_ff, vocab, seq, p.batch)
            }
            PresetSpec::Mlp(_) => bail!("{} is not a transformer-family model", cfg.model),
        },
        None => (128, 512, 256, 64, 16),
    };
    // Layer count of the paper transformer at this scale (the native
    // char-LM mirror is single-block; the energy model prices the
    // full architecture).
    let pl = match cfg.model.as_str() {
        "tfm_small" => 4,
        "tfm_100m" => 12,
        _ => 2,
    };
    let raw = artifacts_available(Path::new(&cfg.artifacts_dir))
        .then(|| Manifest::load(Path::new(&cfg.artifacts_dir)).ok())
        .flatten()
        .and_then(|m| m.model(&cfg.model).map(|info| info.raw));
    let dim = |key: &str, default: usize| {
        raw.as_ref()
            .and_then(|r| r.get(key).and_then(|x| x.as_usize()))
            .unwrap_or(default)
    };
    let (d, l, ff, v, t, b) = (
        dim("d_model", pd),
        dim("n_layer", pl),
        dim("d_ff", pff),
        dim("vocab", pv),
        dim("seq", pt),
        dim("batch", pb),
    );

    let steps = cfg.steps;
    let mut trainer = Trainer::new(cfg)?;
    let n_params: usize = trainer.params.iter().map(|p| p.data.len()).sum();
    println!(
        "model {}: {:.2}M params (d={d}, layers={l}, vocab={v}, seq={t}, batch={b}), backend {}",
        trainer.cfg.model,
        n_params as f64 / 1e6,
        trainer.backend_name()
    );
    println!(
        "training with {} [{}], lr {}, {} steps, Q_U {} bits",
        trainer.cfg.optimizer.name(),
        trainer.cfg.format,
        trainer.cfg.lr,
        steps,
        trainer.cfg.qu_bits
    );

    let macs_per_iter = transformer_macs(d, l, ff, v, t, b);
    let start = Instant::now();
    trainer.run()?;
    let wall = start.elapsed().as_secs_f64();

    let uniform = (v as f64).ln();
    let final_loss = trainer.final_loss(10);
    println!("\n=== E2E result ===");
    println!("  steps: {steps}, wall: {wall:.1}s ({:.2} s/step)", wall / steps as f64);
    println!("  loss: {:.4} -> {final_loss:.4}  (uniform = {uniform:.4})",
        trainer.log.rows.first().and_then(|r| r.values.get("loss")).copied().unwrap_or(f64::NAN));
    println!("  loss curve: {csv}");

    // What this iteration would cost on the paper's accelerator:
    let em = EnergyModel::paper();
    println!("\n  modeled accelerator energy per iteration ({:.2} GMACs):", macs_per_iter / 1e9);
    for f in [
        PeFormat::Lns(ConvertMode::ExactLut),
        PeFormat::Fp8,
        PeFormat::Fp32,
    ] {
        println!("    {:5}: {:.3} mJ", f.name(), em.workload_mj(f, macs_per_iter));
    }
    Ok(())
}
