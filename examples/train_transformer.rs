//! End-to-end driver: train a transformer LM with LNS-Madam through the
//! full three-layer stack (Pallas kernels -> JAX HLO -> PJRT -> rust
//! Madam updates) and log the loss curve. This is the repo's flagship
//! system proof (EXPERIMENTS.md §E2E).
//!
//!   cargo run --release --example train_transformer -- \
//!       [--model tfm_tiny|tfm_small|tfm_100m] [--steps N] [--format lns|fp8|fp32]
//!       [--optimizer madam|sgd|adamw] [--lr X] [--csv path]
//!
//! tfm_small / tfm_100m need `make artifacts-full` / `make artifacts-100m`.

use anyhow::{bail, Result};
use lns_madam::coordinator::{OptKind, TrainConfig, Trainer};
use lns_madam::hw::workload::transformer_macs;
use lns_madam::hw::{EnergyModel, PeFormat};
use lns_madam::lns::ConvertMode;
use lns_madam::runtime::{Manifest, Runtime};
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = TrainConfig::default();
    cfg.model = "tfm_tiny".into();
    cfg.steps = 300;
    cfg.eval_every = 25;
    let mut csv = "train_transformer.csv".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--model" => cfg.model = args[i + 1].clone(),
            "--steps" => cfg.steps = args[i + 1].parse()?,
            "--format" => cfg.format = args[i + 1].clone(),
            "--optimizer" => {
                cfg.optimizer = OptKind::parse(&args[i + 1])?;
                cfg.lr = cfg.optimizer.default_lr();
            }
            "--lr" => cfg.lr = args[i + 1].parse()?,
            "--csv" => csv = args[i + 1].clone(),
            other => bail!("unknown flag {other}"),
        }
        i += 2;
    }
    cfg.log_path = csv.clone();
    cfg.qu_bits = if cfg.format == "lns" { 16 } else { 0 };

    let runtime = Runtime::cpu()?;
    let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
    let model = manifest
        .model(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("model {} not lowered — run make artifacts[-full|-100m]", cfg.model))?;
    let n_params: usize = model.params.iter().map(|p| p.elements()).sum();
    let (d, l, ff, v, t, b) = (
        model.raw.get("d_model").and_then(|x| x.as_usize()).unwrap_or(128),
        model.raw.get("n_layer").and_then(|x| x.as_usize()).unwrap_or(2),
        model.raw.get("d_ff").and_then(|x| x.as_usize()).unwrap_or(512),
        model.raw.get("vocab").and_then(|x| x.as_usize()).unwrap_or(256),
        model.raw.get("seq").and_then(|x| x.as_usize()).unwrap_or(64),
        model.raw.get("batch").and_then(|x| x.as_usize()).unwrap_or(16),
    );
    println!(
        "model {}: {:.2}M params (d={d}, layers={l}, vocab={v}, seq={t}, batch={b})",
        cfg.model,
        n_params as f64 / 1e6
    );
    println!(
        "training with {} [{}], lr {}, {} steps, Q_U {} bits",
        cfg.optimizer.name(),
        cfg.format,
        cfg.lr,
        cfg.steps,
        cfg.qu_bits
    );

    let macs_per_iter = transformer_macs(d, l, ff, v, t, b);
    let steps = cfg.steps;
    let mut trainer = Trainer::new(&runtime, cfg)?;
    let start = Instant::now();
    trainer.run()?;
    let wall = start.elapsed().as_secs_f64();

    let uniform = (v as f64).ln();
    let final_loss = trainer.final_loss(10);
    println!("\n=== E2E result ===");
    println!("  steps: {steps}, wall: {wall:.1}s ({:.2} s/step)", wall / steps as f64);
    println!("  loss: {:.4} -> {final_loss:.4}  (uniform = {uniform:.4})",
        trainer.log.rows.first().and_then(|r| r.values.get("loss")).copied().unwrap_or(f64::NAN));
    println!("  loss curve: {csv}");

    // What this iteration would cost on the paper's accelerator:
    let em = EnergyModel::paper();
    println!("\n  modeled accelerator energy per iteration ({:.2} GMACs):", macs_per_iter / 1e9);
    for f in [
        PeFormat::Lns(ConvertMode::ExactLut),
        PeFormat::Fp8,
        PeFormat::Fp32,
    ] {
        println!("    {:5}: {:.3} mJ", f.name(), em.workload_mj(f, macs_per_iter));
    }
    Ok(())
}
